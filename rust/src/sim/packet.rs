//! Galapagos packets as the simulator sees them.
//!
//! A packet carries the Galapagos bridge header (sender id, receiver id,
//! message size — §2.1 Fig. 2), the TUSER bit16 inter-cluster flag (§4),
//! an optional one-byte GMI header (§5.2), and a payload that is either
//! pure-timing or an actual matrix row (functional simulation).
//!
//! Row payloads are `Arc`-shared: GMI fan-out (Broadcast, the gateway's
//! virtual input broadcast) clones a reference count, not the row bytes.
//!
//! A packet may additionally carry a [`Burst`]: a coalesced run of
//! consecutive rows of the same stream, emitted back-to-back by one
//! kernel over one intra-FPGA edge. One simulator event then stands for
//! the whole run while the per-row emission and arrival times stay
//! cycle-exact (see `fabric::Fabric::deliver_burst` and DESIGN.md
//! "Event coalescing").
//!
//! Packets are `Send + Sync` end to end (payload rows are `Arc`d, never
//! aliased mutably), so the sharded parallel engine moves them through
//! its lock-free cross-shard mailboxes without copying row data; bursts
//! never need to cross a shard boundary because coalescing is
//! intra-FPGA-only and shards are FPGA-aligned (`sim::shard`).

use std::sync::Arc;

use super::params::flits_for_bytes;

/// Hierarchical kernel address: 256 clusters x 256 kernels (§4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GlobalKernelId {
    pub cluster: u8,
    pub kernel: u8,
}

impl GlobalKernelId {
    pub const fn new(cluster: u8, kernel: u8) -> Self {
        GlobalKernelId { cluster, kernel }
    }
    /// The gateway kernel of a cluster is kernel 0 by convention (§4).
    pub const fn gateway_of(cluster: u8) -> Self {
        GlobalKernelId { cluster, kernel: 0 }
    }
    pub fn is_gateway(&self) -> bool {
        self.kernel == 0
    }
    /// Dense 16-bit index (cluster x kernel) used by the simulator's
    /// flat lookup tables — the hot paths never hash kernel ids.
    #[inline]
    pub const fn dense(&self) -> usize {
        ((self.cluster as usize) << 8) | self.kernel as usize
    }
}

/// Size of the dense kernel-id space (`GlobalKernelId::dense`).
pub const DENSE_IDS: usize = 1 << 16;

impl std::fmt::Display for GlobalKernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "c{}k{}", self.cluster, self.kernel)
    }
}

/// Stream metadata: which logical stream of a multi-input kernel this row
/// belongs to, its index, and the total row count of the message (the
/// Galapagos header's "message size").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MsgMeta {
    /// Logical input port tag at the destination (e.g. Q vs K matrix).
    pub stream: u8,
    /// Row index within the message.
    pub row: u32,
    /// Total rows in the message.
    pub rows: u32,
    /// Inference id (for pipelined multi-inference runs).
    pub inference: u32,
}

/// Payload: timing-only or functional data. Row data is `Arc`-shared so
/// fan-out and burst hand-off are O(1).
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Pure-timing packet of the given byte size.
    Timing(usize),
    /// One int8 row (e.g. activations).
    RowI8(Arc<Vec<i8>>),
    /// One int32 row (e.g. matmul accumulators crossing kernels).
    RowI32(Arc<Vec<i32>>),
    /// One int64 row (residual / layernorm domain).
    RowI64(Arc<Vec<i64>>),
    /// Control/token message (barrier, credit, weight-swap command, ...).
    Control(u64),
}

impl Payload {
    pub fn row_i8(v: Vec<i8>) -> Payload {
        Payload::RowI8(Arc::new(v))
    }
    pub fn row_i32(v: Vec<i32>) -> Payload {
        Payload::RowI32(Arc::new(v))
    }
    pub fn row_i64(v: Vec<i64>) -> Payload {
        Payload::RowI64(Arc::new(v))
    }

    pub fn bytes(&self) -> usize {
        match self {
            Payload::Timing(b) => *b,
            Payload::RowI8(v) => v.len(),
            Payload::RowI32(v) => 4 * v.len(),
            Payload::RowI64(v) => 8 * v.len(),
            Payload::Control(_) => 8,
        }
    }
}

/// A coalesced run of consecutive rows carried by a single packet event.
///
/// Rows `meta.row .. meta.row + n` of one stream, emitted by the sender
/// at `emit_times[0..n]` (nondecreasing). The fabric fills `arrivals`
/// with the cycle-exact per-row delivery times — identical to what `n`
/// independent packets sent at the same emission times would have seen,
/// which only holds on intra-FPGA edges where the sender's egress port
/// is the sole serializing resource (the coalescing eligibility rule).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Burst {
    /// Sender-side emission time of each row (len = rows in the burst).
    pub emit_times: Vec<u64>,
    /// Receiver-side arrival time of each row; filled by the fabric.
    pub arrivals: Vec<u64>,
    /// Payloads of rows 1.. (row 0 travels as `Packet::payload`);
    /// `tail.len() + 1 == emit_times.len()`. Every row has the same wire
    /// size as the head payload.
    pub tail: Vec<Payload>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    pub src: GlobalKernelId,
    pub dst: GlobalKernelId,
    /// TUSER bit16: this message leaves the source cluster (§4). Set by the
    /// router model; determines which routing table is consulted.
    pub inter_cluster: bool,
    /// One-byte GMI header carrying the final destination kernel id within
    /// the destination cluster (§5.2). Present iff inter_cluster.
    pub gmi_dst: Option<u8>,
    pub meta: MsgMeta,
    pub payload: Payload,
    /// Coalesced row run (None for an ordinary single-row packet).
    pub burst: Option<Box<Burst>>,
}

impl Packet {
    pub fn new(src: GlobalKernelId, dst: GlobalKernelId, meta: MsgMeta, payload: Payload) -> Self {
        Packet {
            src,
            dst,
            inter_cluster: src.cluster != dst.cluster,
            gmi_dst: None,
            meta,
            payload,
            burst: None,
        }
    }

    /// Wire size of ONE row in bytes: payload + the one-byte GMI header
    /// when attached. Burst rows all share this size.
    pub fn wire_bytes(&self) -> usize {
        self.payload.bytes() + usize::from(self.gmi_dst.is_some())
    }

    /// Serialization cost of one row in flits.
    pub fn flits(&self) -> u64 {
        flits_for_bytes(self.wire_bytes())
    }

    /// Number of rows this packet carries (1 unless coalesced).
    pub fn rows_in_packet(&self) -> usize {
        self.burst.as_ref().map_or(1, |b| b.emit_times.len())
    }

    /// Visit every row as `(meta, arrival, payload)`. For a single packet
    /// the arrival is `now` (the dispatch time); for a burst the fabric's
    /// per-row arrival schedule is used. Rows are visited in row order.
    pub fn for_each_row<F: FnMut(MsgMeta, u64, Payload)>(mut self, now: u64, mut f: F) {
        let meta = self.meta;
        match self.burst.take() {
            None => f(meta, now, self.payload),
            Some(b) => {
                let b = *b;
                debug_assert_eq!(b.tail.len() + 1, b.emit_times.len());
                debug_assert_eq!(b.arrivals.len(), b.emit_times.len());
                f(meta, b.arrivals[0], self.payload);
                for (i, p) in b.tail.into_iter().enumerate() {
                    let m2 = MsgMeta { row: meta.row + 1 + i as u32, ..meta };
                    f(m2, b.arrivals[i + 1], p);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addressing_scheme() {
        let g = GlobalKernelId::gateway_of(7);
        assert!(g.is_gateway());
        assert_eq!(g.cluster, 7);
        assert_eq!(format!("{}", GlobalKernelId::new(1, 2)), "c1k2");
        assert_eq!(GlobalKernelId::new(1, 2).dense(), 258);
    }

    #[test]
    fn inter_cluster_flag_set_from_addresses() {
        let a = GlobalKernelId::new(0, 3);
        let b = GlobalKernelId::new(1, 0);
        let p = Packet::new(a, b, MsgMeta::default(), Payload::Timing(768));
        assert!(p.inter_cluster);
        let q = Packet::new(a, GlobalKernelId::new(0, 5), MsgMeta::default(), Payload::Timing(8));
        assert!(!q.inter_cluster);
    }

    #[test]
    fn gmi_header_costs_one_byte() {
        let a = GlobalKernelId::new(0, 3);
        let b = GlobalKernelId::new(1, 0);
        let mut p = Packet::new(a, b, MsgMeta::default(), Payload::row_i8(vec![0; 768]));
        assert_eq!(p.flits(), 12);
        p.gmi_dst = Some(9);
        assert_eq!(p.wire_bytes(), 769);
        assert_eq!(p.flits(), 13);
    }

    #[test]
    fn payload_sizes() {
        assert_eq!(Payload::row_i32(vec![0; 10]).bytes(), 40);
        assert_eq!(Payload::row_i64(vec![0; 10]).bytes(), 80);
        assert_eq!(Payload::Control(1).bytes(), 8);
        assert_eq!(Payload::Timing(5).bytes(), 5);
    }

    #[test]
    fn payload_fanout_shares_rows() {
        let p = Payload::row_i8(vec![1, 2, 3]);
        let q = p.clone();
        match (&p, &q) {
            (Payload::RowI8(a), Payload::RowI8(b)) => assert!(Arc::ptr_eq(a, b)),
            _ => panic!(),
        }
    }

    #[test]
    fn burst_rows_iterate_in_order() {
        let a = GlobalKernelId::new(0, 3);
        let b = GlobalKernelId::new(0, 5);
        let meta = MsgMeta { stream: 2, row: 10, rows: 13, inference: 1 };
        let mut p = Packet::new(a, b, meta, Payload::Timing(64));
        p.burst = Some(Box::new(Burst {
            emit_times: vec![100, 110, 120],
            arrivals: vec![105, 115, 125],
            tail: vec![Payload::Timing(64), Payload::Timing(64)],
        }));
        assert_eq!(p.rows_in_packet(), 3);
        let mut seen = Vec::new();
        p.for_each_row(0, |m, at, pl| seen.push((m.row, at, pl.bytes())));
        assert_eq!(seen, vec![(10, 105, 64), (11, 115, 64), (12, 125, 64)]);
    }

    #[test]
    fn single_packet_row_uses_dispatch_time() {
        let p = Packet::new(
            GlobalKernelId::new(0, 1),
            GlobalKernelId::new(0, 2),
            MsgMeta { row: 4, ..Default::default() },
            Payload::Timing(8),
        );
        let mut seen = Vec::new();
        p.for_each_row(77, |m, at, _| seen.push((m.row, at)));
        assert_eq!(seen, vec![(4, 77)]);
    }
}
