//! Discrete-event simulator of the multi-FPGA platform — the hardware
//! substitute for the paper's Sidewinder testbed (DESIGN.md substitutions).
//!
//! The model is packet-granular: one Galapagos packet = one matrix row
//! (768 bytes = 12 x 64-byte AXIS flits at the paper's "12 flits per
//! packet"). Kernels are actor-style state machines; the fabric
//! (output switches, routers, NICs, 100G switches) is modeled analytically
//! with per-link serialization so the event count stays O(packets).
//!
//! Large fleets simulate in parallel: [`shard`] cuts the platform at
//! inter-FPGA link boundaries and runs the pieces on worker threads
//! under conservative bounded-window synchronization, with the lookahead
//! derived from the real topology by [`window`] — cycle- and
//! trace-identical to the sequential engine at every thread count.

pub mod engine;
pub mod fabric;
pub mod fifo;
pub mod packet;
pub mod params;
pub mod shard;
pub mod trace;
pub mod window;

pub use engine::{FailurePlan, FailureReport, KernelBehavior, KernelIo, Sim};
pub use fabric::{DropRecord, Fabric, FpgaId, LinkSeq, SwitchId};
pub use packet::{Burst, GlobalKernelId, MsgMeta, Packet, Payload};
pub use shard::ShardGranularity;
