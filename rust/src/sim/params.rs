//! Fabric timing parameters, calibrated from the paper's own measurements
//! (§8.2, §9.4) at the 200 MHz fabric clock derived in DESIGN.md.

/// Bytes per AXIS flit (512-bit datapath, matching 100G line rate at 200 MHz).
pub const FLIT_BYTES: usize = 64;

/// Kernel output switch traversal (AXIS switch in the application region).
pub const OUT_SWITCH_LAT: u64 = 2;

/// Router + Galapagos/Network bridge traversal within one FPGA.
pub const ROUTER_LAT: u64 = 6;

/// NIC (100G MAC + Gulf-Stream UDP core) latency, each direction.
pub const NIC_LAT: u64 = 5;

/// One traversal of a 100G top-of-rack switch. The paper measured a
/// 0.17 us FPGA-to-FPGA ROUND TRIP through one switch (9.4) => 34 cycles
/// RTT at 200 MHz => 17 cycles one way; NIC(5)+switch(7)+NIC(5) = 17.
pub const SWITCH_LAT: u64 = 7;

/// Switch-to-switch hop: the paper measured d = 1.1 us = 220 cycles.
pub const INTER_SWITCH_LAT: u64 = 220;

/// Retransmission timeout of the reliable-transport layer (cycles): the
/// sender declares a copy lost this long after its last flit left the
/// NIC, then re-serializes the packet. 512 cycles = 2.56 us: above the
/// acked round trip of any link the Fig. 17 chain actually uses (adjacent
/// encoders sit one serial switch hop apart, RTT ~= 2 x (17 + 220) = 474
/// cycles) while staying far below any kernel-level latency of interest.
pub const RETX_TIMEOUT: u64 = 512;

/// Number of flits for a payload of `bytes` (ceil; header byte included
/// by the caller when a GMI inter-cluster header is attached).
pub fn flits_for_bytes(bytes: usize) -> u64 {
    (bytes.max(1)).div_ceil(FLIT_BYTES) as u64
}

/// Analytical latency of one packet between two kernels, mirroring the
/// fabric model's uncontended path (`sim::fabric::Fabric::deliver`):
/// kernel output switch + egress serialization + router, then — when the
/// kernels sit on different FPGAs — NIC serialization, NIC/switch/NIC
/// traversal, `switch_hops` serial inter-switch hops, and the ingress
/// router. Shared by the fabric tests and the placer's cost model.
pub fn point_to_point_latency(flits: u64, same_fpga: bool, switch_hops: u64) -> u64 {
    let egress = OUT_SWITCH_LAT + flits + ROUTER_LAT;
    if same_fpga {
        return egress;
    }
    egress + flits + NIC_LAT + SWITCH_LAT + NIC_LAT + switch_hops * INTER_SWITCH_LAT + ROUTER_LAT
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_packet_is_12_flits() {
        // the paper: "each packet contains 12 flits" for a 768-byte row
        assert_eq!(flits_for_bytes(768), 12);
    }

    #[test]
    fn flit_rounding() {
        assert_eq!(flits_for_bytes(1), 1);
        assert_eq!(flits_for_bytes(64), 1);
        assert_eq!(flits_for_bytes(65), 2);
        assert_eq!(flits_for_bytes(769), 13); // +1 header byte spills a flit
    }

    #[test]
    fn point_to_point_matches_fabric_model() {
        // 768-byte row, same constants the fabric tests assert
        assert_eq!(point_to_point_latency(12, true, 0), OUT_SWITCH_LAT + 12 + ROUTER_LAT);
        assert_eq!(
            point_to_point_latency(12, false, 0),
            OUT_SWITCH_LAT + 12 + ROUTER_LAT + 12 + NIC_LAT + SWITCH_LAT + NIC_LAT + ROUTER_LAT
        );
        assert_eq!(
            point_to_point_latency(1, false, 3) - point_to_point_latency(1, false, 0),
            3 * INTER_SWITCH_LAT
        );
    }

    #[test]
    fn rtt_matches_paper() {
        // 9.4: 0.17 us FPGA-to-FPGA round trip through one 100G switch
        let one_way = NIC_LAT + SWITCH_LAT + NIC_LAT;
        let rtt_us = crate::cycles_to_us(2 * one_way);
        assert!((rtt_us - 0.17).abs() < 0.011, "rtt={rtt_us}");
        assert!((crate::cycles_to_us(INTER_SWITCH_LAT) - 1.1).abs() < 1e-9);
    }
}
