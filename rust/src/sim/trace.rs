//! Per-kernel activity counters and arrival probes used by the evaluation
//! harness (Table 1's X/T/I are measured exactly the way the paper did:
//! by watching packets at the evaluation FPGA).

use crate::util::fxhash::FxHashMap;

use super::packet::GlobalKernelId;

#[derive(Debug, Clone, Default)]
pub struct KernelStats {
    pub rx_packets: u64,
    pub tx_packets: u64,
    pub first_rx: Option<u64>,
    pub last_rx: Option<u64>,
    pub first_tx: Option<u64>,
    pub last_tx: Option<u64>,
    pub wakes: u64,
}

impl KernelStats {
    pub fn on_rx(&mut self, t: u64) {
        self.rx_packets += 1;
        self.first_rx.get_or_insert(t);
        self.last_rx = Some(t);
    }
    pub fn on_tx(&mut self, t: u64) {
        self.tx_packets += 1;
        self.first_tx.get_or_insert(t);
        self.last_tx = Some(t);
    }
}

#[derive(Debug, Default)]
pub struct Trace {
    pub kernels: FxHashMap<GlobalKernelId, KernelStats>,
    pub events_processed: u64,
    /// All packet arrival times at "probe" kernels (e.g. the evaluation
    /// FPGA's sink), keyed by probe id — the raw series behind X/T/I.
    pub probes: FxHashMap<GlobalKernelId, Vec<u64>>,
    probe_set: Vec<GlobalKernelId>,
}

impl Trace {
    pub fn stats(&mut self, k: GlobalKernelId) -> &mut KernelStats {
        self.kernels.entry(k).or_default()
    }

    pub fn add_probe(&mut self, k: GlobalKernelId) {
        if !self.probe_set.contains(&k) {
            self.probe_set.push(k);
        }
    }

    pub fn is_probe(&self, k: GlobalKernelId) -> bool {
        self.probe_set.contains(&k)
    }

    pub fn record_probe(&mut self, k: GlobalKernelId, t: u64) {
        self.probes.entry(k).or_default().push(t);
    }

    /// (first, last, median inter-arrival) of a probe's packet series —
    /// the X / T / I decomposition of §8.2.2 when probed at the encoder
    /// output.
    pub fn xti(&self, k: GlobalKernelId) -> Option<(u64, u64, u64)> {
        let v = self.probes.get(&k)?;
        if v.is_empty() {
            return None;
        }
        let first = v[0];
        let last = *v.last().unwrap();
        let mut gaps: Vec<u64> = v.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_unstable();
        let interval = if gaps.is_empty() { 0 } else { gaps[gaps.len() / 2] };
        Some((first, last, interval))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xti_decomposition() {
        let mut tr = Trace::default();
        let k = GlobalKernelId::new(0, 9);
        tr.add_probe(k);
        assert!(tr.is_probe(k));
        for t in [100, 167, 234, 301] {
            tr.record_probe(k, t);
        }
        let (x, t, i) = tr.xti(k).unwrap();
        assert_eq!(x, 100);
        assert_eq!(t, 301);
        assert_eq!(i, 67);
    }

    #[test]
    fn kernel_stats_first_last() {
        let mut s = KernelStats::default();
        s.on_rx(5);
        s.on_rx(9);
        s.on_tx(7);
        assert_eq!(s.first_rx, Some(5));
        assert_eq!(s.last_rx, Some(9));
        assert_eq!(s.rx_packets, 2);
        assert_eq!(s.first_tx, Some(7));
    }
}
