//! Per-kernel activity counters and arrival probes used by the evaluation
//! harness (Table 1's X/T/I are measured exactly the way the paper did:
//! by watching packets at the evaluation FPGA).
//!
//! Stats live in a dense slot vector; a flat 64K id->slot table resolves
//! a `GlobalKernelId` once at registration. The dispatch hot path works
//! purely on slot indices (the seed engine paid two hash lookups per
//! packet: `stats(id)` for rx accounting plus the probe-set scan).

use crate::obs::span::TraceObs;
use crate::sim::packet::{GlobalKernelId, DENSE_IDS};

#[derive(Debug, Clone, Default)]
pub struct KernelStats {
    pub rx_packets: u64,
    pub tx_packets: u64,
    pub first_rx: Option<u64>,
    pub last_rx: Option<u64>,
    pub first_tx: Option<u64>,
    pub last_tx: Option<u64>,
    pub wakes: u64,
}

impl KernelStats {
    pub fn on_rx(&mut self, t: u64) {
        self.rx_packets += 1;
        self.first_rx = Some(self.first_rx.map_or(t, |f| f.min(t)));
        self.last_rx = Some(self.last_rx.map_or(t, |l| l.max(t)));
    }
    pub fn on_tx(&mut self, t: u64) {
        self.tx_packets += 1;
        self.first_tx = Some(self.first_tx.map_or(t, |f| f.min(t)));
        self.last_tx = Some(self.last_tx.map_or(t, |l| l.max(t)));
    }

    /// Fold another counter set in (shard merge-back): counts add,
    /// first/last take min/max across both.
    pub(crate) fn absorb(&mut self, o: &KernelStats) {
        self.rx_packets += o.rx_packets;
        self.tx_packets += o.tx_packets;
        self.wakes += o.wakes;
        let min = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, y) => x.or(y),
        };
        let max = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(x), Some(y)) => Some(x.max(y)),
            (x, y) => x.or(y),
        };
        self.first_rx = min(self.first_rx, o.first_rx);
        self.last_rx = max(self.last_rx, o.last_rx);
        self.first_tx = min(self.first_tx, o.first_tx);
        self.last_tx = max(self.last_tx, o.last_tx);
    }
}

#[derive(Debug)]
pub struct Trace {
    /// dense per-kernel stats, parallel to `ids`.
    slots: Vec<KernelStats>,
    ids: Vec<GlobalKernelId>,
    /// dense id -> slot + 1; 0 = unregistered.
    slot16: Box<[u32]>,
    /// per-slot probe flag + probe-series index (+1; 0 = none).
    probe_flag: Vec<bool>,
    probe_series: Vec<u32>,
    series: Vec<Vec<u64>>,
    pub events_processed: u64,
    /// Optional telemetry collector (None = telemetry off; the hot
    /// paths below pay a single not-taken branch per event).
    pub obs: Option<Box<TraceObs>>,
}

impl Default for Trace {
    fn default() -> Self {
        Trace {
            slots: Vec::new(),
            ids: Vec::new(),
            slot16: vec![0u32; DENSE_IDS].into_boxed_slice(),
            probe_flag: Vec::new(),
            probe_series: Vec::new(),
            series: Vec::new(),
            events_processed: 0,
            obs: None,
        }
    }
}

impl Trace {
    /// Resolve (or create) the dense stats slot of `k` — done once per
    /// kernel at registration time, never on the dispatch path.
    pub fn register(&mut self, k: GlobalKernelId) -> usize {
        let d = k.dense();
        match self.slot16[d] {
            0 => {
                let slot = self.slots.len();
                self.slots.push(KernelStats::default());
                self.ids.push(k);
                self.probe_flag.push(false);
                self.probe_series.push(0);
                self.slot16[d] = slot as u32 + 1;
                if let Some(o) = &mut self.obs {
                    o.marks.push(o.is_marked_dense(d as u32));
                }
                slot
            }
            s => s as usize - 1,
        }
    }

    pub fn stats(&mut self, k: GlobalKernelId) -> &mut KernelStats {
        let slot = self.register(k);
        &mut self.slots[slot]
    }

    /// Read-only stats lookup by kernel id (None if it never appeared).
    pub fn kernel(&self, k: GlobalKernelId) -> Option<&KernelStats> {
        match self.slot16[k.dense()] {
            0 => None,
            s => Some(&self.slots[s as usize - 1]),
        }
    }

    /// All (id, stats) pairs in registration order.
    pub fn kernels(&self) -> impl Iterator<Item = (GlobalKernelId, &KernelStats)> {
        self.ids.iter().copied().zip(self.slots.iter())
    }

    // ---- slot-indexed hot paths (engine dispatch) ----

    #[inline]
    pub fn on_rx_slot(&mut self, slot: usize, t: u64) {
        self.slots[slot].on_rx(t);
    }
    #[inline]
    pub fn on_tx_slot(&mut self, slot: usize, t: u64) {
        self.slots[slot].on_tx(t);
    }
    #[inline]
    pub fn on_tx_burst(&mut self, slot: usize, times: &[u64]) {
        for &t in times {
            self.slots[slot].on_tx(t);
        }
    }
    #[inline]
    pub fn wake_slot(&mut self, slot: usize) {
        self.slots[slot].wakes += 1;
    }
    #[inline]
    pub fn probe_slot(&self, slot: usize) -> bool {
        self.probe_flag[slot]
    }

    // ---- telemetry hooks (single Option branch when disabled) ----

    /// Enable the telemetry collector: `marked` kernels get
    /// per-inference endpoint stats (span roles); everything else only
    /// feeds the fleet-level bucket series.
    pub fn enable_obs(&mut self, interval: u64, marked: &[GlobalKernelId]) {
        let mut o = Box::new(TraceObs::new(
            interval,
            marked.iter().map(|k| k.dense() as u32).collect(),
        ));
        let marks: Vec<bool> =
            self.ids.iter().map(|id| o.is_marked_dense(id.dense() as u32)).collect();
        o.marks = marks;
        self.obs = Some(o);
    }

    /// Interval + mark set needed to build a matching per-shard
    /// collector (None when telemetry is off).
    pub(crate) fn obs_spec(&self) -> Option<(u64, Vec<u32>)> {
        self.obs.as_ref().map(|o| (o.interval, o.mark_set.clone()))
    }

    /// A packet delivery: bump the bucket event series, and when the
    /// receiving kernel is marked, its per-inference endpoint stats.
    #[inline]
    pub fn obs_rx(&mut self, slot: usize, inference: u32, t: u64) {
        if let Some(o) = &mut self.obs {
            o.on_event(t);
            if o.marks[slot] {
                o.on_rx_marked(self.ids[slot].dense() as u32, inference, t);
            }
        }
    }

    /// A packet send from a marked kernel.
    #[inline]
    pub fn obs_tx(&mut self, slot: usize, inference: u32, t: u64) {
        if let Some(o) = &mut self.obs {
            if o.marks[slot] {
                o.on_tx_marked(self.ids[slot].dense() as u32, inference, t);
            }
        }
    }

    /// A wake delivery: counts as an event and into the wake series.
    #[inline]
    pub fn obs_wake(&mut self, t: u64) {
        if let Some(o) = &mut self.obs {
            o.on_event(t);
            o.on_wake_bucket(t);
        }
    }

    /// Sample a FIFO depth into the fleet-peak bucket series.
    #[inline]
    pub fn obs_fifo_depth(&mut self, t: u64, occupancy: u64) {
        if let Some(o) = &mut self.obs {
            o.on_fifo_depth(t, occupancy);
        }
    }
    #[inline]
    pub fn record_probe_slot(&mut self, slot: usize, t: u64) {
        let si = self.probe_series[slot];
        debug_assert!(si != 0, "record_probe_slot on a non-probe slot");
        self.series[si as usize - 1].push(t);
    }

    /// Fold a per-shard trace back into the master (parallel-engine
    /// teardown): per-kernel counters add, probe series append in the
    /// shard's (chronological) recording order, event counts add. Each
    /// kernel lives in exactly one shard, so no series interleaving is
    /// ever needed.
    pub(crate) fn absorb(&mut self, other: Trace) {
        self.events_processed += other.events_processed;
        for (i, id) in other.ids.iter().enumerate() {
            let slot = self.register(*id);
            self.slots[slot].absorb(&other.slots[i]);
            if other.probe_flag[i] {
                self.add_probe(*id);
                let si = self.probe_series[slot] as usize - 1;
                let osi = other.probe_series[i] as usize - 1;
                self.series[si].extend_from_slice(&other.series[osi]);
            }
        }
        if let (Some(mine), Some(theirs)) = (&mut self.obs, other.obs) {
            mine.merge(*theirs);
        }
    }

    // ---- probe API ----

    pub fn add_probe(&mut self, k: GlobalKernelId) {
        let slot = self.register(k);
        if !self.probe_flag[slot] {
            self.probe_flag[slot] = true;
            self.series.push(Vec::new());
            self.probe_series[slot] = self.series.len() as u32;
        }
    }

    pub fn is_probe(&self, k: GlobalKernelId) -> bool {
        match self.slot16[k.dense()] {
            0 => false,
            s => self.probe_flag[s as usize - 1],
        }
    }

    pub fn record_probe(&mut self, k: GlobalKernelId, t: u64) {
        let slot = self.register(k);
        self.record_probe_slot(slot, t);
    }

    /// The raw arrival-time series of a probe (empty/None if unprobed).
    pub fn probe_times(&self, k: GlobalKernelId) -> Option<&[u64]> {
        let s = match self.slot16[k.dense()] {
            0 => return None,
            s => s as usize - 1,
        };
        match self.probe_series[s] {
            0 => None,
            si => Some(&self.series[si as usize - 1]),
        }
    }

    /// (first, last, median inter-arrival) of a probe's packet series —
    /// the X / T / I decomposition of §8.2.2 when probed at the encoder
    /// output.
    pub fn xti(&self, k: GlobalKernelId) -> Option<(u64, u64, u64)> {
        let v = self.probe_times(k)?;
        if v.is_empty() {
            return None;
        }
        let first = v[0];
        let last = *v.last().unwrap();
        let mut gaps: Vec<u64> = v.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_unstable();
        let interval = if gaps.is_empty() { 0 } else { gaps[gaps.len() / 2] };
        Some((first, last, interval))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xti_decomposition() {
        let mut tr = Trace::default();
        let k = GlobalKernelId::new(0, 9);
        tr.add_probe(k);
        assert!(tr.is_probe(k));
        for t in [100, 167, 234, 301] {
            tr.record_probe(k, t);
        }
        let (x, t, i) = tr.xti(k).unwrap();
        assert_eq!(x, 100);
        assert_eq!(t, 301);
        assert_eq!(i, 67);
    }

    #[test]
    fn kernel_stats_first_last() {
        let mut s = KernelStats::default();
        s.on_rx(5);
        s.on_rx(9);
        s.on_tx(7);
        assert_eq!(s.first_rx, Some(5));
        assert_eq!(s.last_rx, Some(9));
        assert_eq!(s.rx_packets, 2);
        assert_eq!(s.first_tx, Some(7));
    }

    #[test]
    fn registration_is_idempotent_and_dense() {
        let mut tr = Trace::default();
        let a = GlobalKernelId::new(3, 4);
        let b = GlobalKernelId::new(200, 2);
        let sa = tr.register(a);
        let sb = tr.register(b);
        assert_ne!(sa, sb);
        assert_eq!(tr.register(a), sa);
        tr.on_rx_slot(sa, 10);
        assert_eq!(tr.kernel(a).unwrap().rx_packets, 1);
        assert!(tr.kernel(GlobalKernelId::new(1, 1)).is_none());
        assert_eq!(tr.kernels().count(), 2);
    }

    #[test]
    fn absorb_merges_counts_series_and_extremes() {
        let a = GlobalKernelId::new(0, 1);
        let b = GlobalKernelId::new(0, 2);
        let mut master = Trace::default();
        master.add_probe(a);
        master.record_probe(a, 5);
        master.stats(a).on_rx(5);
        master.events_processed = 3;

        let mut sh = Trace::default();
        sh.register(a);
        sh.add_probe(a);
        sh.record_probe(a, 9);
        sh.stats(a).on_rx(9);
        sh.stats(a).on_tx(11);
        sh.stats(b).on_rx(2);
        sh.events_processed = 4;

        master.absorb(sh);
        assert_eq!(master.events_processed, 7);
        let sa = master.kernel(a).unwrap();
        assert_eq!((sa.rx_packets, sa.tx_packets), (2, 1));
        assert_eq!((sa.first_rx, sa.last_rx), (Some(5), Some(9)));
        assert_eq!(master.probe_times(a).unwrap(), &[5, 9]);
        assert_eq!(master.kernel(b).unwrap().first_rx, Some(2));
    }

    #[test]
    fn obs_marks_follow_registration_and_absorb_merges() {
        let a = GlobalKernelId::new(0, 1);
        let b = GlobalKernelId::new(0, 2);
        let mut tr = Trace::default();
        tr.register(a); // registered before enable: mark backfilled
        tr.enable_obs(100, &[a, b]);
        let sb = tr.register(b); // registered after enable
        let sa = tr.register(a);
        tr.obs_rx(sa, 7, 50);
        tr.obs_tx(sb, 7, 90);
        tr.obs_wake(150);
        tr.obs_fifo_depth(55, 768);
        let o = tr.obs.as_ref().unwrap();
        assert_eq!(o.mark(a.dense() as u32, 7).unwrap().first_rx, Some(50));
        assert_eq!(o.mark(b.dense() as u32, 7).unwrap().last_tx, Some(90));
        assert_eq!(o.bucket_events, vec![1, 1]);
        assert_eq!(o.bucket_wakes, vec![0, 1]);
        assert_eq!(o.bucket_fifo_peak, vec![768]);

        // shard-style merge
        let mut sh = Trace::default();
        sh.enable_obs(100, &[a, b]);
        let ssa = sh.register(a);
        sh.obs_rx(ssa, 7, 40);
        tr.absorb(sh);
        let o = tr.obs.as_ref().unwrap();
        let m = o.mark(a.dense() as u32, 7).unwrap();
        assert_eq!((m.first_rx, m.rx_packets), (Some(40), 2));
        assert_eq!(o.bucket_events, vec![2, 1]);
    }

    #[test]
    fn obs_disabled_is_a_noop() {
        let mut tr = Trace::default();
        let s = tr.register(GlobalKernelId::new(0, 1));
        tr.obs_rx(s, 0, 10);
        tr.obs_wake(10);
        tr.obs_fifo_depth(10, 99);
        assert!(tr.obs.is_none());
        assert!(tr.obs_spec().is_none());
    }

    #[test]
    fn probes_by_slot_match_probes_by_id() {
        let mut tr = Trace::default();
        let k = GlobalKernelId::new(0, 7);
        let slot = tr.register(k);
        assert!(!tr.probe_slot(slot));
        tr.add_probe(k);
        assert!(tr.probe_slot(slot));
        tr.record_probe_slot(slot, 42);
        tr.record_probe(k, 43);
        assert_eq!(tr.probe_times(k).unwrap(), &[42, 43]);
    }
}
