//! Multi-tenant placement: pack N independent model graphs onto ONE
//! fleet, with per-tenant resource accounting and per-tenant-minimal
//! recovery.
//!
//! The single-model placer maps one [`KernelGraph`] onto a [`Fleet`];
//! a multi-tenant fleet hosts several tenants — possibly different
//! shapes — at once. The packing discipline is *spatial partitioning*:
//! tenants are placed in declaration order, each taking the minimal
//! contiguous run of remaining slots that admits its graph
//! ([`search::place_on_prefix`]). Tenants therefore never share an FPGA,
//! which buys three properties the serving layer leans on:
//!
//! * **accounting** — a tenant's resource ledger is exactly the sum of
//!   its kernels' usage on its own slots ([`TenantPlacement::usage`]);
//!   no cross-tenant attribution problem exists by construction;
//! * **isolation** — one tenant's placement (and its recovery) is a
//!   pure function of its own sub-fleet, so a noisy or failing tenant
//!   cannot move another tenant's kernels;
//! * **determinism** — the packing order alone fixes the outcome, so
//!   multi-tenant plans inherit the placer's reproducibility contract.
//!
//! Recovery ([`recover_multi`]) maps a failed global slot to its owning
//! tenant and re-places *only* that tenant's displaced kernels within
//! its own sub-fleet (possibly degrading it); every other tenant's
//! mapping is untouched — asserted, not just intended.

use anyhow::{bail, ensure, Result};

use super::cost::LatencyEstimate;
use super::recover::{replace_after_failure, Move, RecoverySolution};
use super::search::{place_on_prefix, SearchParams};
use super::{Fleet, KernelGraph, ModelShape, Placement};
use crate::fpga::resources::{ResourceBudget, ResourceUsage};
use crate::ibert::timing::PeConfig;

/// One tenant's placement request: a model shape plus the sequence
/// length its cost model should optimize for.
#[derive(Debug, Clone)]
pub struct TenantGraphSpec {
    pub name: String,
    pub shape: ModelShape,
    /// sequence length for `SearchParams::for_m` (the tenant's `max_m`)
    pub m: usize,
}

impl TenantGraphSpec {
    /// Model shapes addressable by name in tenant config files.
    pub fn shape_by_name(name: &str) -> Option<ModelShape> {
        match name {
            "ibert-base" => Some(ModelShape::ibert_base()),
            "bert-large" => Some(ModelShape::bert_large()),
            _ => None,
        }
    }
}

/// One tenant's share of a packed fleet.
#[derive(Debug, Clone)]
pub struct TenantPlacement {
    pub name: String,
    pub graph: KernelGraph,
    /// kernel -> slot mapping, LOCAL to the tenant's sub-fleet
    pub placement: Placement,
    /// first global fleet slot of the tenant's contiguous range
    pub slot_base: usize,
    /// width of the allocated range (`slot_base..slot_base + slots`)
    pub slots: usize,
    pub predicted: LatencyEstimate,
    /// aggregate usage of every kernel, on the slots it landed on — the
    /// tenant's ledger line in the fleet's resource accounting
    pub usage: ResourceUsage,
}

impl TenantPlacement {
    /// Kernel -> GLOBAL fleet slot (local placement + base offset).
    pub fn global_slot_of(&self) -> Vec<usize> {
        self.placement.slot_of.iter().map(|&s| s + self.slot_base).collect()
    }

    /// Total budget of the tenant's allocated slots.
    pub fn allocated_budget(&self, fleet: &Fleet) -> ResourceBudget {
        let mut b = ResourceBudget { lut: 0, ff: 0, bram18: 0, dsp: 0 };
        for s in self.slot_base..self.slot_base + self.slots {
            let d = fleet.budget(s);
            b.lut += d.lut;
            b.ff += d.ff;
            b.bram18 += d.bram18;
            b.dsp += d.dsp;
        }
        b
    }

    /// Worst per-resource utilisation of the tenant's aggregate usage
    /// against its allocated budget (the accounting headline).
    pub fn max_utilisation(&self, fleet: &Fleet) -> f64 {
        self.usage.max_utilisation(&self.allocated_budget(fleet))
    }
}

/// N tenants packed onto one fleet.
#[derive(Debug, Clone)]
pub struct MultiPlacement {
    pub fleet: Fleet,
    pub tenants: Vec<TenantPlacement>,
}

impl MultiPlacement {
    /// Which tenant owns a global fleet slot (None = unallocated tail).
    pub fn tenant_of_slot(&self, slot: usize) -> Option<usize> {
        self.tenants
            .iter()
            .position(|t| (t.slot_base..t.slot_base + t.slots).contains(&slot))
    }

    /// The sub-fleet allocated to tenant `t`.
    pub fn sub_fleet(&self, t: usize) -> Fleet {
        let tp = &self.tenants[t];
        Fleet {
            devices: self.fleet.devices[tp.slot_base..tp.slot_base + tp.slots].to_vec(),
            fpgas_per_switch: self.fleet.fpgas_per_switch,
            util_cap: self.fleet.util_cap,
        }
    }

    /// Global slots still unallocated after the packing.
    pub fn free_slots(&self) -> usize {
        let used: usize = self.tenants.iter().map(|t| t.slots).sum();
        self.fleet.n_slots() - used
    }
}

/// Pack `specs` onto `fleet` in declaration order: each tenant takes the
/// minimal contiguous run of remaining slots that places its shape.
/// Fails (naming the tenant) when the remaining slots cannot admit one.
pub fn place_multi(
    specs: &[TenantGraphSpec],
    pe: &PeConfig,
    fleet: &Fleet,
) -> Result<MultiPlacement> {
    fleet.validate()?;
    ensure!(!specs.is_empty(), "place_multi needs at least one tenant");
    {
        let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        ensure!(names.len() == specs.len(), "tenant names must be unique");
        ensure!(specs.iter().all(|s| !s.name.is_empty()), "tenant names must be non-empty");
    }

    let mut tenants = Vec::with_capacity(specs.len());
    let mut cursor = 0usize;
    for spec in specs {
        spec.shape.validate()?;
        let remaining = Fleet {
            devices: fleet.devices[cursor..].to_vec(),
            fpgas_per_switch: fleet.fpgas_per_switch,
            util_cap: fleet.util_cap,
        };
        if remaining.devices.is_empty() {
            bail!(
                "fleet exhausted before tenant '{}': {} slots already allocated",
                spec.name,
                cursor
            );
        }
        let sp = SearchParams::for_m(spec.m.clamp(1, spec.shape.max_seq));
        let (slots, sol) = place_on_prefix(&spec.shape, pe, &remaining, &sp).map_err(|e| {
            anyhow::anyhow!(
                "tenant '{}' does not fit the remaining {} fleet slots: {e}",
                spec.name,
                remaining.n_slots()
            )
        })?;
        let usage: ResourceUsage = (0..sol.graph.n_kernels())
            .map(|k| {
                sol.graph.usage(k as u8, remaining.device(sol.placement.slot_of[k]))
            })
            .sum();
        tenants.push(TenantPlacement {
            name: spec.name.clone(),
            graph: sol.graph,
            placement: sol.placement,
            slot_base: cursor,
            slots,
            predicted: sol.predicted,
            usage,
        });
        cursor += slots;
    }
    Ok(MultiPlacement { fleet: fleet.clone(), tenants })
}

/// One tenant's recovery inside a multi-tenant fleet.
#[derive(Debug, Clone)]
pub struct MultiRecovery {
    /// index into `MultiPlacement::tenants` of the tenant that failed
    pub tenant: usize,
    pub name: String,
    /// the tenant-local recovery (slots relative to its sub-fleet)
    pub solution: RecoverySolution,
    /// the same moves in global fleet slots
    pub moved_global: Vec<Move>,
}

/// Re-place after the failure of global slot `failed_slot`: the owning
/// tenant's displaced kernels are re-packed onto the *survivors of its
/// own sub-fleet* (degrading that tenant alone if it must overcommit);
/// every other tenant's placement is untouched by construction.
pub fn recover_multi(mp: &MultiPlacement, failed_slot: usize, m: usize) -> Result<MultiRecovery> {
    ensure!(failed_slot < mp.fleet.n_slots(), "failed slot {failed_slot} outside the fleet");
    let Some(t) = mp.tenant_of_slot(failed_slot) else {
        bail!("slot {failed_slot} is unallocated: nothing to recover");
    };
    let tp = &mp.tenants[t];
    let sub = mp.sub_fleet(t);
    let local = failed_slot - tp.slot_base;
    let solution = replace_after_failure(&tp.graph, &tp.placement, &sub, local, m)?;
    let moved_global = solution.moved.iter().map(|mv| mv.offset(tp.slot_base)).collect();
    Ok(MultiRecovery { tenant: t, name: tp.name.clone(), solution, moved_global })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::Device;

    fn three_tenants() -> Vec<TenantGraphSpec> {
        vec![
            TenantGraphSpec { name: "chat".into(), shape: ModelShape::ibert_base(), m: 128 },
            TenantGraphSpec { name: "search".into(), shape: ModelShape::bert_large(), m: 64 },
            TenantGraphSpec { name: "batch".into(), shape: ModelShape::ibert_base(), m: 32 },
        ]
    }

    #[test]
    fn mixed_shapes_pack_disjoint_contiguous_ranges() {
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 30, 6);
        let mp = place_multi(&three_tenants(), &PeConfig::default(), &fleet).unwrap();
        assert_eq!(mp.tenants.len(), 3);
        // contiguous, disjoint, in declaration order
        let mut cursor = 0;
        for t in &mp.tenants {
            assert_eq!(t.slot_base, cursor, "tenant '{}' range must be contiguous", t.name);
            assert!(t.slots >= 1);
            cursor += t.slots;
        }
        assert!(cursor <= 30);
        assert_eq!(mp.free_slots(), 30 - cursor);
        // every kernel stays inside its tenant's range
        for t in &mp.tenants {
            for &s in &t.global_slot_of() {
                assert!((t.slot_base..t.slot_base + t.slots).contains(&s));
            }
        }
        // bert-large auto-splits its FFN and needs a wider range
        assert!(mp.tenants[1].graph.shape.ffn_split >= 2);
        assert!(mp.tenants[1].slots > mp.tenants[0].slots);
        // ownership lookup round-trips
        for (i, t) in mp.tenants.iter().enumerate() {
            assert_eq!(mp.tenant_of_slot(t.slot_base), Some(i));
            assert_eq!(mp.tenant_of_slot(t.slot_base + t.slots - 1), Some(i));
        }
        assert_eq!(mp.tenant_of_slot(29), None, "tail slots stay unallocated");
    }

    #[test]
    fn per_tenant_accounting_fits_allocated_budgets() {
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 30, 6);
        let mp = place_multi(&three_tenants(), &PeConfig::default(), &fleet).unwrap();
        for t in &mp.tenants {
            assert!(t.usage.lut > 0 && t.usage.bram18 > 0, "'{}' ledger is non-trivial", t.name);
            let util = t.max_utilisation(&fleet);
            assert!(util > 0.0 && util <= 1.0, "'{}' at {util:.2} of its allocation", t.name);
        }
        // the ledger is per-kernel usage summed — recompute independently
        let t0 = &mp.tenants[0];
        let sub = mp.sub_fleet(0);
        let recomputed: ResourceUsage = (0..t0.graph.n_kernels())
            .map(|k| t0.graph.usage(k as u8, sub.device(t0.placement.slot_of[k])))
            .sum();
        assert_eq!(t0.usage, recomputed);
    }

    #[test]
    fn heterogeneous_fleet_packs_in_slot_order() {
        // a mixed fleet: the first tenant takes the leading XCZU19EGs,
        // the second lands on whatever follows (including Versal parts)
        let mut devices = vec![Device::Xczu19eg; 8];
        devices.extend(vec![Device::Xcvc1902; 8]);
        let fleet = Fleet { devices, fpgas_per_switch: 6, util_cap: 0.85 };
        let specs = vec![
            TenantGraphSpec { name: "a".into(), shape: ModelShape::ibert_base(), m: 128 },
            TenantGraphSpec { name: "b".into(), shape: ModelShape::ibert_base(), m: 128 },
        ];
        let mp = place_multi(&specs, &PeConfig::default(), &fleet).unwrap();
        assert_eq!(mp.tenants[0].slot_base, 0);
        assert_eq!(mp.tenants[1].slot_base, mp.tenants[0].slots);
        // sub-fleet devices really are the global fleet's slice
        let sub1 = mp.sub_fleet(1);
        let base = mp.tenants[1].slot_base;
        for (i, d) in sub1.devices.iter().enumerate() {
            assert_eq!(*d, mp.fleet.device(base + i));
        }
    }

    #[test]
    fn recovery_touches_only_the_owning_tenant() {
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 30, 6);
        let mp = place_multi(&three_tenants(), &PeConfig::default(), &fleet).unwrap();
        // fail a slot owned by tenant 1 (bert-large)
        let failed = mp.tenants[1].slot_base + 1;
        assert_eq!(mp.tenant_of_slot(failed), Some(1));
        let rec = recover_multi(&mp, failed, 64).unwrap();
        assert_eq!((rec.tenant, rec.name.as_str()), (1, "search"));
        // the local recovery never references slots outside the sub-fleet
        let width = mp.tenants[1].slots;
        assert!(rec.solution.placement.slot_of.iter().all(|&s| s < width));
        // global moves stay inside the owner's range and off the dead slot
        let range = mp.tenants[1].slot_base..mp.tenants[1].slot_base + width;
        for mv in &rec.moved_global {
            assert_eq!(mv.from, failed);
            assert!(range.contains(&mv.to) && mv.to != failed);
        }
        // tenants 0 and 2 are untouched: same struct, same placements —
        // recovery does not even take them as input, but assert anyway
        assert_eq!(rec.solution.moved.len(), rec.moved_global.len());
        for (i, t) in mp.tenants.iter().enumerate() {
            if i != 1 {
                assert!(!t.global_slot_of().iter().any(|&s| s == failed));
            }
        }
    }

    #[test]
    fn packing_failures_name_the_tenant() {
        // 8 slots: the first tenant fits, bert-large cannot
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 8, 6);
        let specs = vec![
            TenantGraphSpec { name: "small".into(), shape: ModelShape::ibert_base(), m: 128 },
            TenantGraphSpec { name: "big".into(), shape: ModelShape::bert_large(), m: 128 },
        ];
        let err = place_multi(&specs, &PeConfig::default(), &fleet).unwrap_err().to_string();
        assert!(err.contains("big"), "{err}");
        // duplicate names are rejected up front
        let dup = vec![
            TenantGraphSpec { name: "x".into(), shape: ModelShape::ibert_base(), m: 128 },
            TenantGraphSpec { name: "x".into(), shape: ModelShape::ibert_base(), m: 128 },
        ];
        let err = place_multi(&dup, &PeConfig::default(), &fleet).unwrap_err().to_string();
        assert!(err.contains("unique"), "{err}");
    }

    #[test]
    fn recovering_an_unallocated_slot_is_an_error() {
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 30, 6);
        let specs =
            vec![TenantGraphSpec { name: "only".into(), shape: ModelShape::ibert_base(), m: 128 }];
        let mp = place_multi(&specs, &PeConfig::default(), &fleet).unwrap();
        assert!(mp.free_slots() > 0);
        let err = recover_multi(&mp, 29, 128).unwrap_err().to_string();
        assert!(err.contains("unallocated"), "{err}");
        assert!(recover_multi(&mp, 99, 128).is_err());
    }

    #[test]
    fn shape_names_resolve() {
        assert_eq!(TenantGraphSpec::shape_by_name("ibert-base"), Some(ModelShape::ibert_base()));
        assert_eq!(TenantGraphSpec::shape_by_name("bert-large"), Some(ModelShape::bert_large()));
        assert_eq!(TenantGraphSpec::shape_by_name("gpt-5"), None);
    }
}
