//! Human-readable placement reports for the CLI `plan` subcommand.

use crate::util::table::{pct, Table};
use crate::{cycles_to_us, FABRIC_CLOCK_HZ};

use super::multi::MultiPlacement;
use super::validate::SlotReport;
use super::{Fleet, KernelGraph, Placement, PlacementSolution};

/// Kernel -> FPGA assignment table.
pub fn placement_table(g: &KernelGraph, p: &Placement, fleet: &Fleet) -> Table {
    let mut t = Table::new(
        "Placement (kernel -> FPGA slot)",
        &["kern", "name", "stage", "slot", "device", "switch"],
    );
    for node in &g.nodes {
        let slot = p.slot_of[node.id as usize];
        t.row(vec![
            format!("{}", node.id),
            node.name.clone(),
            format!("{}", node.role.stage()),
            format!("{slot}"),
            fleet.device(slot).name().to_string(),
            format!("{}", fleet.switch_of(slot)),
        ]);
    }
    t
}

/// Per-FPGA utilisation table (the placement's Fig. 15 analogue).
pub fn utilisation_table(reports: &[SlotReport]) -> Table {
    let mut t = Table::new(
        "Per-FPGA utilisation",
        &["slot", "device", "kernels", "LUT", "FF", "BRAM", "DSP", "fit"],
    );
    for r in reports {
        let (l, f, b, d) = r.utilisation();
        t.row(vec![
            format!("{}", r.slot),
            r.device.name().to_string(),
            format!("{}", r.kernels.len()),
            pct(l),
            pct(f),
            pct(b),
            pct(d),
            if r.fits() { "OK".into() } else { "OVER".into() },
        ]);
    }
    t
}

/// One-paragraph latency summary: per-encoder (X, T, I) plus the Eq. 1
/// chain estimate for an `encoders`-deep model.
pub fn latency_summary(
    sol: &PlacementSolution,
    m: usize,
    encoders: usize,
    d_cycles: u64,
) -> String {
    let e = sol.predicted;
    let chain = e.chain_cycles(encoders, d_cycles);
    format!(
        "predicted @ m={m}: X = {} cycles ({:.2} us)   T = {} cycles ({:.2} us)   I = {} cycles\n\
         {} FPGAs used, {} local-search moves, FFN split {}\n\
         Eq. 1 chain ({} encoders, d = {:.2} us): {:.3} ms  ->  {:.1} inferences/s (unpipelined)",
        e.x,
        cycles_to_us(e.x),
        e.t,
        cycles_to_us(e.t),
        e.i,
        sol.slots_used,
        sol.moves_applied,
        sol.graph.shape.ffn_split,
        encoders,
        cycles_to_us(d_cycles),
        cycles_to_us(chain) / 1000.0,
        FABRIC_CLOCK_HZ as f64 / chain as f64
    )
}

/// Per-tenant packing table for `plan --tenants`: one ledger row per
/// tenant — slot range, shape, FFN split, aggregate utilisation of the
/// allocated sub-fleet, and the predicted per-encoder T.
pub fn multi_tenant_table(mp: &MultiPlacement) -> Table {
    let mut t = Table::new(
        "Multi-tenant packing (tenant -> fleet slots)",
        &["tenant", "slots", "shape", "split", "kernels", "peak util", "T (us)"],
    );
    for tp in &mp.tenants {
        let s = tp.graph.shape;
        t.row(vec![
            tp.name.clone(),
            format!("{}..{}", tp.slot_base, tp.slot_base + tp.slots - 1),
            format!("{}x{}x{}h", s.hidden, s.ffn, s.heads),
            format!("{}", s.ffn_split),
            format!("{}", tp.graph.n_kernels()),
            pct(tp.max_utilisation(&mp.fleet)),
            format!("{:.2}", cycles_to_us(tp.predicted.t)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ibert::timing::PeConfig;
    use crate::placer::{validate, ModelShape};

    #[test]
    fn tables_render_for_fig14() {
        let g = KernelGraph::encoder(ModelShape::ibert_base(), PeConfig::default()).unwrap();
        let p = Placement::fig14();
        let fleet = Fleet::paper();
        let pt = placement_table(&g, &p, &fleet).render();
        assert!(pt.contains("gmi-gather-heads"));
        assert!(pt.contains("xczu19eg"));
        let reports = validate::check(&g, &p, &fleet).unwrap();
        let ut = utilisation_table(&reports).render();
        assert!(ut.contains("OK"));
        assert!(!ut.contains("OVER"));
    }

    #[test]
    fn multi_tenant_table_lists_every_tenant() {
        use crate::fpga::resources::Device;
        use crate::placer::{place_multi, TenantGraphSpec};
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 30, 6);
        let specs = vec![
            TenantGraphSpec { name: "alpha".into(), shape: ModelShape::ibert_base(), m: 128 },
            TenantGraphSpec { name: "beta".into(), shape: ModelShape::bert_large(), m: 64 },
        ];
        let mp = place_multi(&specs, &PeConfig::default(), &fleet).unwrap();
        let out = multi_tenant_table(&mp).render();
        assert!(out.contains("alpha") && out.contains("beta"));
        assert!(out.contains("768x3072x12h") && out.contains("1024x4096x16h"));
    }
}
