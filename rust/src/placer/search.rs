//! Placement search: greedy bin-packing seeded by the paper's layer
//! order, refined by cost-model-guided local-search moves.
//!
//! The seed mirrors how the paper's authors mapped Fig. 14 by hand: walk
//! the pipeline stage by stage, opening a fresh FPGA per stage while the
//! fleet allows it (spatial pipelining wants stages on separate devices)
//! and first-fit-packing each stage's kernels under the utilisation cap.
//! Stages that overflow a device spill onto additional FPGAs; fleets
//! smaller than the stage count make stages share.
//!
//! The refinement pass then tries single-kernel moves, keeping any move
//! that improves predicted end-to-end latency by more than `min_gain`
//! while staying within every device's capped budget. The threshold
//! keeps the search from churning on sub-0.1% wins (and keeps the paper
//! configuration exactly on its Fig. 14 fixed point, which no move can
//! improve meaningfully).

use anyhow::{bail, Result};

use super::cost::{estimate, LatencyEstimate};
use super::{ensure_placeable, Fleet, KernelGraph, ModelShape, Placement};
use crate::fpga::resources::{ResourceBudget, ResourceUsage};
use crate::ibert::timing::PeConfig;

/// Search knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchParams {
    /// sequence length the cost model optimizes for
    pub m: usize,
    /// input row interval in cycles (12 = 100G line rate, §8.2.2)
    pub input_interval: u64,
    /// minimum relative latency gain for a move to be applied
    pub min_gain: f64,
    /// local-search sweeps over all kernels
    pub max_passes: usize,
}

impl SearchParams {
    pub fn for_m(m: usize) -> SearchParams {
        SearchParams { m, input_interval: 12, min_gain: 0.002, max_passes: 3 }
    }
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams::for_m(128)
    }
}

/// A finished placement search.
#[derive(Debug, Clone)]
pub struct PlacementSolution {
    pub graph: KernelGraph,
    pub placement: Placement,
    pub predicted: LatencyEstimate,
    pub slots_used: usize,
    pub moves_applied: usize,
}

/// Map `shape` onto `fleet`: build the kernel graph (raising the FFN
/// split until every kernel fits some device), seed greedily, refine by
/// local search, and resource-check the result against full budgets.
pub fn place(
    shape: &ModelShape,
    pe: &PeConfig,
    fleet: &Fleet,
    sp: &SearchParams,
) -> Result<PlacementSolution> {
    fleet.validate()?;
    let m = sp.m.clamp(1, shape.max_seq);

    // auto-split: double the FFN parallelisation until each kernel can
    // fit at least one device of the fleet on its own
    let mut graph = None;
    let mut split = shape.ffn_split;
    while split <= 8 {
        if shape.ffn % split == 0 {
            let g = KernelGraph::encoder(shape.with_ffn_split(split), *pe)?;
            if ensure_placeable(&g, fleet).is_ok() {
                graph = Some(g);
                break;
            }
        }
        split *= 2;
    }
    let Some(graph) = graph else {
        // re-run the checker at the base split for its diagnostic
        let g = KernelGraph::encoder(*shape, *pe)?;
        ensure_placeable(&g, fleet)?;
        bail!("no FFN split in 1..=8 makes shape {shape:?} placeable on this fleet");
    };

    // prefer one FPGA per pipeline stage (spatial pipelining, Fig. 14);
    // when the fleet is too small for that, fall back to pure first-fit
    let mut placement = greedy_seed(&graph, fleet, true)
        .or_else(|stage_err| greedy_seed(&graph, fleet, false).map_err(|_| stage_err))?;
    let moves_applied = refine(&graph, &mut placement, fleet, m, sp)?;

    // final acceptance is against FULL device budgets (the cap is only
    // the packer's headroom target)
    super::validate::check(&graph, &placement, fleet)?;
    let predicted = estimate(&graph, &placement, fleet, m, sp.input_interval)?;
    let slots_used = placement.used_slots().len();
    Ok(PlacementSolution { graph, placement, predicted, slots_used, moves_applied })
}

/// Place `shape` on the smallest *prefix* of `fleet` that admits it: try
/// `devices[..n]` for growing `n` and return the first success together
/// with the prefix width. This is the deterministic building block of
/// multi-tenant packing (`placer::multi::place_multi`): each tenant takes
/// the minimal contiguous run of remaining slots, so tenants never share
/// an FPGA and the packing order alone fixes the outcome.
pub fn place_on_prefix(
    shape: &ModelShape,
    pe: &PeConfig,
    fleet: &Fleet,
    sp: &SearchParams,
) -> Result<(usize, PlacementSolution)> {
    fleet.validate()?;
    let mut last_err = None;
    for n in 1..=fleet.n_slots() {
        let sub = Fleet {
            devices: fleet.devices[..n].to_vec(),
            fpgas_per_switch: fleet.fpgas_per_switch,
            util_cap: fleet.util_cap,
        };
        match place(shape, pe, &sub, sp) {
            Ok(sol) => return Ok((n, sol)),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.expect("fleet.validate() guarantees at least one slot"))
}

fn fits(used: ResourceUsage, budget: &ResourceBudget) -> bool {
    used.fits(budget)
}

/// Greedy bin-packing in the paper's layer order. With `fresh_per_stage`
/// each pipeline stage opens a new FPGA while the fleet has one (the
/// variant that reproduces Fig. 14); without it, kernels first-fit into
/// the fleet front-to-back (denser, for small fleets).
fn greedy_seed(g: &KernelGraph, fleet: &Fleet, fresh_per_stage: bool) -> Result<Placement> {
    let n_slots = fleet.n_slots();
    let mut used: Vec<ResourceUsage> = (0..n_slots).map(|s| fleet.base_usage(s)).collect();
    let mut occupied = vec![false; n_slots];
    let mut slot_of = vec![usize::MAX; g.n_kernels()];
    let mut frontier = 0usize; // highest slot opened so far

    for (stage_idx, stage) in g.stages().into_iter().enumerate() {
        let mut cur = 0;
        if fresh_per_stage {
            cur = frontier;
            if stage_idx > 0 && occupied[frontier] && frontier + 1 < n_slots {
                frontier += 1;
                cur = frontier;
            }
        }
        for id in stage {
            let candidates = (cur..n_slots).chain(0..cur);
            let mut placed = false;
            for s in candidates {
                let need = used[s] + g.usage(id, fleet.device(s));
                if fits(need, &fleet.capped_budget(s)) {
                    used[s] = need;
                    occupied[s] = true;
                    slot_of[id as usize] = s;
                    frontier = frontier.max(s);
                    placed = true;
                    break;
                }
            }
            if !placed {
                bail!(
                    "fleet too small: kernel {} ({}) does not fit on any of the {} FPGAs \
                     under the {:.0}% utilisation cap",
                    id,
                    g.node(id).name,
                    n_slots,
                    fleet.util_cap * 100.0
                );
            }
        }
    }
    Ok(Placement { slot_of })
}

/// Local search: single-kernel moves accepted on > min_gain latency
/// improvement. Returns the number of moves applied.
fn refine(
    g: &KernelGraph,
    placement: &mut Placement,
    fleet: &Fleet,
    m: usize,
    sp: &SearchParams,
) -> Result<usize> {
    let n_slots = fleet.n_slots();
    let mut used: Vec<ResourceUsage> = (0..n_slots).map(|s| fleet.base_usage(s)).collect();
    for (k, &s) in placement.slot_of.iter().enumerate() {
        used[s] += g.usage(k as u8, fleet.device(s));
    }
    let mut cost = estimate(g, placement, fleet, m, sp.input_interval)?.t;
    let mut moves = 0usize;

    for _pass in 0..sp.max_passes {
        let mut improved = false;
        for &id in g.placement_order() {
            let from = placement.slot_of[id as usize];
            // feasible target slots; each candidate's latency estimate is
            // independent, so score them on the worker pool
            let cands: Vec<usize> = (0..n_slots)
                .filter(|&to| {
                    to != from && fits(used[to] + g.usage(id, fleet.device(to)),
                                       &fleet.capped_budget(to))
                })
                .collect();
            let scores: Vec<Option<u64>> = if cands.len() >= 4 {
                let base = &*placement;
                crate::util::pool::parallel_map(&cands, |&to| {
                    let mut p2 = base.clone();
                    p2.slot_of[id as usize] = to;
                    estimate(g, &p2, fleet, m, sp.input_interval).ok().map(|e| e.t)
                })
            } else {
                cands
                    .iter()
                    .map(|&to| {
                        placement.slot_of[id as usize] = to;
                        let e = estimate(g, placement, fleet, m, sp.input_interval);
                        placement.slot_of[id as usize] = from;
                        e.ok().map(|e| e.t)
                    })
                    .collect()
            };
            // keep the serial tie-break: the earliest slot with a strict win
            let mut best: Option<(usize, u64)> = None;
            for (&to, t) in cands.iter().zip(&scores) {
                if let Some(t) = *t {
                    if t < best.map_or(cost, |(_, c)| c) {
                        best = Some((to, t));
                    }
                }
            }
            if let Some((to, new_cost)) = best {
                let gain = (cost - new_cost) as f64 / cost.max(1) as f64;
                if gain > sp.min_gain {
                    let u_from = g.usage(id, fleet.device(from));
                    used[from] = ResourceUsage {
                        lut: used[from].lut - u_from.lut,
                        ff: used[from].ff - u_from.ff,
                        bram18: used[from].bram18 - u_from.bram18,
                        dsp: used[from].dsp - u_from.dsp,
                    };
                    used[to] += g.usage(id, fleet.device(to));
                    placement.slot_of[id as usize] = to;
                    cost = new_cost;
                    moves += 1;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    Ok(moves)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::Device;
    use crate::ibert::graph::fpga_slot;

    #[test]
    fn paper_fleet_reproduces_fig14() {
        let sol = place(
            &ModelShape::ibert_base(),
            &PeConfig::default(),
            &Fleet::paper(),
            &SearchParams::default(),
        )
        .unwrap();
        let want: Vec<usize> = (0..38u8).map(fpga_slot).collect();
        assert_eq!(sol.placement.slot_of, want, "must reproduce the Fig. 14 mapping");
        assert_eq!(sol.slots_used, 6);
    }

    #[test]
    fn smaller_fleet_merges_stages() {
        // four FPGAs: the six stages must share devices but still fit
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 4, 6);
        let sol = place(
            &ModelShape::ibert_base(),
            &PeConfig::default(),
            &fleet,
            &SearchParams::default(),
        )
        .unwrap();
        assert!(sol.slots_used <= 4);
        super::super::validate::check(&sol.graph, &sol.placement, &fleet).unwrap();
    }

    #[test]
    fn one_fpga_fleet_is_rejected_for_paper_shape() {
        // everything on one XCZU19EG blows the BRAM budget
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 1, 6);
        assert!(place(
            &ModelShape::ibert_base(),
            &PeConfig::default(),
            &fleet,
            &SearchParams::default(),
        )
        .is_err());
    }

    #[test]
    fn prefix_placement_is_minimal_and_matches_plain_place() {
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 12, 6);
        let (n, sol) = place_on_prefix(
            &ModelShape::ibert_base(),
            &PeConfig::default(),
            &fleet,
            &SearchParams::default(),
        )
        .unwrap();
        assert!(n >= sol.slots_used, "prefix covers every used slot");
        assert!(n < 12, "I-BERT-base must not need the whole 12-slot fleet");
        assert!(sol.placement.slot_of.iter().all(|&s| s < n));
        // minimality: the next-smaller prefix must be infeasible
        if n > 1 {
            let smaller = Fleet::homogeneous(Device::Xczu19eg, n - 1, 6);
            assert!(place(
                &ModelShape::ibert_base(),
                &PeConfig::default(),
                &smaller,
                &SearchParams::default(),
            )
            .is_err());
        }
        // and the solution is exactly what place() yields on that prefix
        let sub = Fleet::homogeneous(Device::Xczu19eg, n, 6);
        let direct = place(
            &ModelShape::ibert_base(),
            &PeConfig::default(),
            &sub,
            &SearchParams::default(),
        )
        .unwrap();
        assert_eq!(sol.placement.slot_of, direct.placement.slot_of);
    }

    #[test]
    fn bert_large_auto_splits_ffn() {
        // a monolithic 1024x4096 FFN exceeds one XCZU19EG; the search
        // must raise the split and still produce a fitting plan
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 12, 6);
        let sol = place(
            &ModelShape::bert_large(),
            &PeConfig::default(),
            &fleet,
            &SearchParams::default(),
        )
        .unwrap();
        assert!(sol.graph.shape.ffn_split >= 2, "FFN must be split");
        assert!(sol.slots_used > 6, "BERT-large needs more FPGAs than the paper config");
    }
}
