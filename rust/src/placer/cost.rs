//! Communication-aware latency model for candidate placements.
//!
//! Mirrors the discrete-event simulator's semantics analytically so the
//! local search can score thousands of candidate placements without
//! running events:
//!
//! * compute kernels pace rows with `ibert::timing` initiation intervals
//!   through the same `EmitPacer` recurrence the simulator uses
//!   (first-out = first-in + fill + II; steady-state interval = II);
//! * GMI kernels forward immediately but serialize on their egress port
//!   (`sim::params::FLIT_BYTES` flits per packet);
//! * the K / V streams *gate* the attention kernels: nothing is emitted
//!   until the buffered matrix is complete — exactly the simulator's
//!   `drain_ready` behaviour;
//! * every edge pays the same hop latency the fabric model charges
//!   (`sim::params::point_to_point_latency`), including the d = 1.1 us
//!   inter-switch term when a placement straddles switches.
//!
//! Known deviations from the simulator (documented in DESIGN.md): NIC
//! egress contention between kernels sharing an FPGA is not modelled,
//! and GMI forwarding is charged one serialization per packet rather
//! than per queued backlog. Both are second-order at row granularity;
//! `validate::replay_in_simulator` cross-checks the model end-to-end.

use anyhow::{ensure, Result};

use super::{Fleet, KernelGraph, KernelRole, Placement};
use crate::sim::params::{flits_for_bytes, point_to_point_latency};

/// Predicted (X, T, I) of one encoder at a given sequence length — the
/// same triple the evaluation sink measures (§8.2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyEstimate {
    /// cycles until the first output row leaves the encoder
    pub x: u64,
    /// cycles until the last output row leaves the encoder
    pub t: u64,
    /// steady-state interval between output rows
    pub i: u64,
}

impl LatencyEstimate {
    /// Eq. 1 (§8.2.2): full-model latency for a chain of `encoders`
    /// encoder clusters with inter-cluster hop latency `d_cycles`.
    pub fn chain_cycles(&self, encoders: usize, d_cycles: u64) -> u64 {
        crate::eval::latency_model::estimate_model_latency_cycles(
            crate::eval::latency_model::LatencyComponents { x: self.x, t: self.t, i: self.i },
            encoders,
            d_cycles,
        )
    }
}

/// A tenant's unloaded chain latency as a fraction of its SLO budget:
/// Eq. 1 extrapolation of this placement over `encoders` clusters,
/// divided by `slo_p99_us` in fabric cycles. Above 1.0 the plan cannot
/// meet the SLO even with zero queueing — `plan --tenants` prints this
/// so infeasible SLO targets are caught before serving, and the serving
/// admission controller charges queueing on top of it.
pub fn slo_fraction(est: &LatencyEstimate, encoders: usize, d_cycles: u64, slo_p99_us: f64) -> f64 {
    let budget = slo_p99_us * 1e-6 * crate::FABRIC_CLOCK_HZ as f64;
    if budget <= 0.0 {
        return f64::INFINITY;
    }
    est.chain_cycles(encoders, d_cycles) as f64 / budget
}

/// Per-role initiation interval (cycles between output rows) at actual
/// sequence length `m` — the `ibert::timing` models the simulator uses.
fn role_ii(role: KernelRole, g: &KernelGraph, m: usize) -> u64 {
    let pe = &g.pe;
    let (h, f) = (g.shape.hidden as u64, g.shape.ffn as u64);
    let d = g.shape.head_dim() as u64;
    let fpart = f / g.shape.ffn_split as u64;
    let m = m as u64;
    match role {
        KernelRole::LinearQ | KernelRole::LinearK | KernelRole::LinearV | KernelRole::Proj => {
            pe.qkv_row_cycles(h)
        }
        KernelRole::AttnHead(_) => pe.attn_row_cycles(m, d) + pe.softmax_row_cycles(m),
        KernelRole::SmmHead(_) => pe.smm_row_cycles(m, d),
        KernelRole::Ln1 | KernelRole::Ln2 => pe.ln_row_cycles(h),
        KernelRole::Ffn1(_) => pe.linear_row_cycles(h, fpart, pe.ffn_macs),
        KernelRole::Ffn2(_) => pe.linear_row_cycles(fpart, h, pe.ffn_macs),
        // GMI / gateway kernels forward; only egress serialization paces
        KernelRole::Gateway
        | KernelRole::ScatterQ
        | KernelRole::ScatterK
        | KernelRole::ScatterV
        | KernelRole::GatherHeads
        | KernelRole::BcastLn1
        | KernelRole::FfnReduce => 0,
    }
}

fn role_fill(role: KernelRole, g: &KernelGraph) -> u64 {
    if role_ii(role, g, 1) == 0 {
        0
    } else {
        g.pe.pipe_fill
    }
}

/// Timing state of one kernel's output stream.
#[derive(Debug, Clone, Copy, Default)]
struct Stream {
    first: u64,
    last: u64,
}

/// The conservative parallel-simulation lookahead a placement yields
/// under the finest (per-FPGA) shard cut: the minimum 1-flit
/// point-to-point latency over every ordered pair of distinct used FPGA
/// slots — exactly what `sim::window` derives for
/// `ShardGranularity::PerFpga`. It is a *lower bound* for any coarser
/// cut: the default per-encoder granularity only keeps the
/// cross-encoder pairs, whose latency is at least this and typically
/// gains the full d = 1.1 us serial switch hop. Larger is better (fewer
/// barrier rounds per simulated second). `None` for single-slot
/// placements (nothing to cut — the simulator falls back to its
/// sequential engine).
pub fn min_lookahead_cycles(placement: &Placement, fleet: &Fleet) -> Option<u64> {
    let used = placement.used_slots();
    let sw = |slot: usize| slot / fleet.fpgas_per_switch.max(1);
    let mut best: Option<u64> = None;
    for &a in &used {
        for &b in &used {
            if a == b {
                continue;
            }
            let lat = point_to_point_latency(1, false, sw(a).abs_diff(sw(b)) as u64);
            best = Some(best.map_or(lat, |x: u64| x.min(lat)));
        }
    }
    best
}

/// Below this window the barrier rounds of the sharded engine cost more
/// than they buy — `plan` warns when the retransmit clamp pushes the
/// lookahead under it. Two same-switch hops (~66 cycles) is roughly
/// where barrier overhead and window work break even on current hosts.
pub const PROFITABLE_WINDOW_CYCLES: u64 = 64;

/// [`min_lookahead_cycles`] as the sharded engine actually applies it
/// under reliable lossy transport: the engine clamps the conservative
/// window to `RETX_TIMEOUT` (`sim::params`) because a retransmitted
/// boundary copy re-enters the sender NIC `RETX_TIMEOUT` cycles after
/// the original send, and the clamp keeps the conservative claim
/// locally checkable without the retries-only-add-latency argument
/// (`Sim::run_parallel` mirrors this). The clamp only binds on cuts
/// wider than `RETX_TIMEOUT` — at default parameters that means 3+
/// inter-switch hops.
pub fn retx_aware_lookahead_cycles(placement: &Placement, fleet: &Fleet) -> Option<u64> {
    min_lookahead_cycles(placement, fleet).map(|w| w.min(crate::sim::params::RETX_TIMEOUT))
}

/// Estimate (X, T, I) of one encoder under `placement` at sequence
/// length `m`, with input rows injected every `input_interval` cycles
/// from the evaluation FPGA (slot = one past the fleet's last used slot,
/// mirroring the simulator testbed).
pub fn estimate(
    g: &KernelGraph,
    placement: &Placement,
    fleet: &Fleet,
    m: usize,
    input_interval: u64,
) -> Result<LatencyEstimate> {
    ensure!(m >= 1, "sequence length must be positive");
    ensure!(
        m <= g.shape.max_seq,
        "sequence length {m} exceeds the build's max_seq {}",
        g.shape.max_seq
    );
    let n = g.n_kernels();
    ensure!(
        placement.slot_of.len() == n,
        "placement covers {} kernels, graph has {n}",
        placement.slot_of.len()
    );

    let io_slot = placement.n_slots(); // the evaluation FPGA
    let sw = |slot: usize| slot / fleet.fpgas_per_switch.max(1);
    let hop = |a: usize, b: usize, bytes: usize| -> u64 {
        let hops = sw(a).abs_diff(sw(b)) as u64;
        point_to_point_latency(flits_for_bytes(bytes), a == b, hops)
    };

    // per-kernel egress work per row: total flits across all out-edges
    let mut out_flits = vec![0u64; n];
    for e in &g.edges {
        out_flits[e.src as usize] += flits_for_bytes(g.edge_bytes(e, m));
    }
    let ids = g.shape.ids();
    // the encoder output row leaves with a one-byte GMI header
    out_flits[ids.ln2 as usize] += flits_for_bytes(g.shape.hidden + 1);

    // external input: eval source -> gateway, inter-cluster (+1B header)
    let in_bytes = g.shape.hidden + 1;
    let src_interval = input_interval.max(flits_for_bytes(in_bytes));
    let ext_lat = hop(io_slot, placement.slot_of[ids.gateway as usize], in_bytes);
    let ext = Stream { first: ext_lat, last: (m as u64 - 1) * src_interval + ext_lat };

    let mut out: Vec<Stream> = vec![Stream::default(); n];
    let rows = m as u64;
    for &u in g.topo_order() {
        let id = u as u8;
        let role = g.node(id).role;
        let slot = placement.slot_of[u];

        // pacing inputs pair per-row (max of firsts / lasts); gating
        // inputs hold emission until their entire stream has arrived
        let mut p_first = 0u64;
        let mut p_last = 0u64;
        let mut gate = 0u64;
        let mut any_input = false;
        for &ei in g.in_edge_indices(id) {
            let e = &g.edges[ei];
            let lat = hop(placement.slot_of[e.src as usize], slot, g.edge_bytes(e, m));
            let s = out[e.src as usize];
            if e.gating {
                gate = gate.max(s.last + lat);
            } else {
                p_first = p_first.max(s.first + lat);
                p_last = p_last.max(s.last + lat);
            }
            any_input = true;
        }
        if role == KernelRole::Gateway {
            p_first = p_first.max(ext.first);
            p_last = p_last.max(ext.last);
            any_input = true;
        }
        ensure!(any_input, "kernel {id} has no inputs");

        let ii = role_ii(role, g, m);
        let fill = role_fill(role, g);
        let eff = ii.max(out_flits[u]);
        let first_ready = p_first.max(gate);
        let last_ready = p_last.max(gate);
        out[u] = if ii > 0 {
            // EmitPacer: row r emits at max(arr_r + fill + II, prev + II)
            let first = first_ready + fill + eff;
            Stream { first, last: (last_ready + fill + eff).max(first + (rows - 1) * eff) }
        } else {
            // GMI forwarding: immediate, paced only by egress flits
            Stream { first: first_ready, last: last_ready.max(first_ready + (rows - 1) * eff) }
        };
    }

    // encoder output -> evaluation sink (inter-cluster, +1B header)
    let out_lat = hop(placement.slot_of[ids.ln2 as usize], io_slot, g.shape.hidden + 1);
    let s = out[ids.ln2 as usize];
    let (x, t) = (s.first + out_lat, s.last + out_lat);
    let i = if m > 1 { (t - x) / (m as u64 - 1) } else { 0 };
    Ok(LatencyEstimate { x, t, i })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::Device;
    use crate::ibert::timing::PeConfig;
    use crate::placer::ModelShape;

    fn paper() -> (KernelGraph, Placement, Fleet) {
        let g = KernelGraph::encoder(ModelShape::ibert_base(), PeConfig::default()).unwrap();
        (g, Placement::fig14(), Fleet::paper())
    }

    #[test]
    fn paper_estimate_has_table1_shape() {
        // Table 1 anchors at m=128: I ~ 767, T ~ 210k, X/T ~ 0.53
        let (g, p, f) = paper();
        let e = estimate(&g, &p, &f, 128, 12).unwrap();
        assert!((700..=850).contains(&e.i), "I should be ~767, got {}", e.i);
        assert!((180_000..=240_000).contains(&e.t), "T should be ~210k, got {}", e.t);
        let ratio = e.x as f64 / e.t as f64;
        assert!((0.40..=0.65).contains(&ratio), "X/T ~ 0.53, got {ratio:.3}");
    }

    #[test]
    fn estimate_scales_with_sequence_length() {
        let (g, p, f) = paper();
        let mut prev = 0;
        for m in [16, 32, 64, 128] {
            let e = estimate(&g, &p, &f, m, 12).unwrap();
            assert!(e.t > prev, "T must grow with m (m={m}: {} <= {prev})", e.t);
            prev = e.t;
        }
        let t16 = estimate(&g, &p, &f, 16, 12).unwrap().t;
        assert!(t16 * 3 < prev, "no-padding short sequences must be much cheaper");
    }

    #[test]
    fn cross_switch_placement_costs_more() {
        // same mapping, but only 2 FPGAs per switch: the pipeline now
        // crosses switches and pays d = 1.1 us per extra hop
        let (g, p, mut f) = paper();
        let t_one_switch = estimate(&g, &p, &f, 128, 12).unwrap().t;
        f.fpgas_per_switch = 2;
        let t_chained = estimate(&g, &p, &f, 128, 12).unwrap().t;
        assert!(t_chained > t_one_switch, "{t_chained} <= {t_one_switch}");
    }

    #[test]
    fn single_fpga_placement_is_cheapest_in_comm() {
        // all kernels on one (hypothetically infinite) FPGA: T drops
        // because every hop becomes intra-FPGA — the cost model must see
        // communication, not just compute
        let (g, p, f) = paper();
        let all_zero = Placement { slot_of: vec![0; g.n_kernels()] };
        let t_spread = estimate(&g, &p, &f, 128, 12).unwrap().t;
        let t_merged = estimate(&g, &all_zero, &f, 128, 12).unwrap().t;
        assert!(t_merged < t_spread, "{t_merged} >= {t_spread}");
        // ... but only marginally: the pipeline is compute-bound
        assert!((t_spread - t_merged) * 50 < t_spread, "comm should be second-order");
    }

    #[test]
    fn lookahead_tracks_the_simulators_window() {
        let (g, p, f) = paper();
        // Fig. 14 on one switch: cheapest cross-slot edge is the 1-flit
        // same-switch inter-FPGA path = 33 cycles (sim::window's floor)
        assert_eq!(min_lookahead_cycles(&p, &f), Some(33));
        // 2 FPGAs per switch: some pair still shares a switch
        let mut f2 = f.clone();
        f2.fpgas_per_switch = 2;
        assert_eq!(min_lookahead_cycles(&p, &f2), Some(33));
        // one FPGA per switch: every cut pays at least one serial hop
        f2.fpgas_per_switch = 1;
        assert_eq!(
            min_lookahead_cycles(&p, &f2),
            Some(33 + crate::sim::params::INTER_SWITCH_LAT)
        );
        // single-slot placement: nothing to cut
        let merged = Placement { slot_of: vec![0; g.n_kernels()] };
        assert_eq!(min_lookahead_cycles(&merged, &f), None);
    }

    #[test]
    fn retx_aware_lookahead_clamps_only_wide_cuts() {
        use crate::sim::params::{INTER_SWITCH_LAT, RETX_TIMEOUT};
        let (g, p, f) = paper();
        // one switch: 33 cycles, far below RETX_TIMEOUT — no clamp
        assert_eq!(retx_aware_lookahead_cycles(&p, &f), Some(33));
        // one FPGA per switch: 33 + 220 = 253 — still below the clamp
        let mut f2 = f.clone();
        f2.fpgas_per_switch = 1;
        assert_eq!(retx_aware_lookahead_cycles(&p, &f2), Some(33 + INTER_SWITCH_LAT));
        // a hypothetical 3-hop-wide cut would exceed RETX_TIMEOUT and
        // must clamp: check the math directly against the raw lookahead
        assert!(33 + 3 * INTER_SWITCH_LAT > RETX_TIMEOUT, "clamp threshold moved");
        // single-slot placement: nothing to cut in either view
        let merged = Placement { slot_of: vec![0; g.n_kernels()] };
        assert_eq!(retx_aware_lookahead_cycles(&merged, &f), None);
        // at default fabric parameters the clamp (512) can never push a
        // window under the profitable floor (64) — the plan warning
        // guards RETX_TIMEOUT/topology parameter changes, not defaults
        assert!(PROFITABLE_WINDOW_CYCLES < RETX_TIMEOUT);
    }

    #[test]
    fn chain_uses_eq1() {
        let e = LatencyEstimate { x: 100, t: 250, i: 5 };
        assert_eq!(e.chain_cycles(1, 220), 250);
        assert_eq!(e.chain_cycles(12, 220), 250 + 11 * 320);
        assert_eq!(e.chain_cycles(0, 220), 250); // saturates, no underflow
    }

    #[test]
    fn slo_fraction_scales_with_budget_and_chain() {
        let e = LatencyEstimate { x: 100, t: 250, i: 5 };
        // one cluster at 250 cycles; a 250-cycle budget is exactly 1.0
        let budget_us = 250.0 / crate::FABRIC_CLOCK_HZ as f64 * 1e6;
        let f1 = slo_fraction(&e, 1, 220, budget_us);
        assert!((f1 - 1.0).abs() < 1e-9, "{f1}");
        // doubling the budget halves the fraction; longer chains raise it
        assert!((slo_fraction(&e, 1, 220, 2.0 * budget_us) - 0.5).abs() < 1e-9);
        assert!(slo_fraction(&e, 12, 220, budget_us) > f1);
        // degenerate budgets are infeasible, not a division crash
        assert_eq!(slo_fraction(&e, 1, 220, 0.0), f64::INFINITY);
        assert_eq!(slo_fraction(&e, 1, 220, -5.0), f64::INFINITY);
    }

    #[test]
    fn rejects_m_beyond_build_capacity() {
        let (g, p, f) = paper();
        assert!(estimate(&g, &p, &f, 129, 12).is_err());
        assert!(estimate(&g, &p, &f, 0, 12).is_err());
    }

    #[test]
    fn bert_large_estimate_runs() {
        let shape = ModelShape::bert_large().with_ffn_split(2);
        let g = KernelGraph::encoder(shape, PeConfig::default()).unwrap();
        let f = Fleet::homogeneous(Device::Xczu19eg, 12, 6);
        // stage-per-slot seed placement (roughly): just spread by stage
        let slots: Vec<usize> = (0..g.n_kernels() as u8)
            .map(|id| g.node(id).role.stage().min(f.n_slots() - 1))
            .collect();
        let e = estimate(&g, &Placement { slot_of: slots }, &f, 128, 12).unwrap();
        assert!(e.t > e.x && e.x > 0);
    }
}
