//! Automatic partitioner/placer: map transformer encoder graphs onto
//! heterogeneous multi-FPGA fleets.
//!
//! The paper's central argument is that multi-FPGA ML needs *tooling to
//! describe a large application and map it to multiple FPGAs*; its own
//! mapping (Fig. 14/18, 38 kernels over six XCZU19EG) was done by hand.
//! This subsystem automates that step for any encoder shape:
//!
//! * [`KernelGraph::encoder`] generalises the Fig. 14 graph to any
//!   `hidden` / `ffn` / `heads` / `max_seq` (plus a column/row-parallel
//!   FFN split for shapes whose FFN weights exceed one device);
//! * [`search::place`] packs kernels onto a [`Fleet`] (possibly mixing
//!   device types) — greedy bin-packing seeded by the paper's layer
//!   order, refined by local-search moves;
//! * [`cost`] scores candidate placements with a communication-aware
//!   latency model built on the same pacing/serialization rules as the
//!   discrete-event simulator (`ibert::timing`, `sim::params`);
//! * [`validate`] checks completeness + per-device `ResourceBudget` fit
//!   and replays paper-shaped placements through the simulator;
//! * [`report`] renders placements as the CLI's `plan` tables;
//! * [`multi`] packs N independent tenant graphs onto ONE fleet
//!   (spatial partitioning with per-tenant accounting and
//!   per-tenant-minimal recovery — `plan --tenants` / `serve --tenants`).
//!
//! For the paper's own configuration (I-BERT-base on six XCZU19EG behind
//! one switch) the search reproduces the Fig. 14 mapping exactly.

pub mod cost;
pub mod multi;
pub mod recover;
pub mod report;
pub mod search;
pub mod validate;

pub use cost::LatencyEstimate;
pub use multi::{place_multi, recover_multi, MultiPlacement, TenantGraphSpec, TenantPlacement};
pub use recover::{replace_after_failure, ReconfigModel, RecoverySolution};
pub use search::{place, place_on_prefix, PlacementSolution, SearchParams};

use anyhow::{bail, ensure, Result};

use crate::fpga::resources::{
    batched_kv_cache_bram18, kv_cache_bram18, Device, ResourceBudget, ResourceUsage,
};
use crate::ibert::timing::PeConfig;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Model shape
// ---------------------------------------------------------------------------

/// Shape of one encoder layer — the placer's input is *any* shape, not
/// just I-BERT-base.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelShape {
    pub hidden: usize,
    pub ffn: usize,
    pub heads: usize,
    /// sequence capacity of the hardware build (FIFO sizing)
    pub max_seq: usize,
    /// column/row-parallel split of the FFN linears (1 = the paper's
    /// monolithic FFN kernels; >1 inserts a GMI Reduce for the partial
    /// sums — the Layer Description File's parallelisation knob, §6.1)
    pub ffn_split: usize,
}

impl ModelShape {
    /// The paper's test application (§7): I-BERT-base.
    pub fn ibert_base() -> Self {
        ModelShape { hidden: 768, ffn: 3072, heads: 12, max_seq: 128, ffn_split: 1 }
    }

    /// BERT-large-shaped encoder (the first scaling target past the paper).
    pub fn bert_large() -> Self {
        ModelShape { hidden: 1024, ffn: 4096, heads: 16, max_seq: 128, ffn_split: 1 }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn with_ffn_split(mut self, split: usize) -> Self {
        self.ffn_split = split;
        self
    }

    /// True iff this is the shape the Fig. 14 six-FPGA build implements
    /// (and therefore the shape the simulator testbed can replay).
    pub fn is_paper_shape(&self) -> bool {
        self.hidden == 768 && self.ffn == 3072 && self.heads == 12 && self.ffn_split == 1
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.heads >= 1 && self.heads <= 64, "heads must be 1..=64");
        ensure!(self.hidden >= self.heads, "hidden smaller than head count");
        ensure!(self.hidden % self.heads == 0, "hidden must divide evenly into heads");
        ensure!(self.ffn >= 1 && self.max_seq >= 1, "ffn and max_seq must be positive");
        ensure!(self.ffn_split >= 1 && self.ffn_split <= 8, "ffn_split must be 1..=8");
        ensure!(self.ffn % self.ffn_split == 0, "ffn must divide evenly into ffn_split");
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Kernel roles and the generalized encoder graph
// ---------------------------------------------------------------------------

/// What a kernel *is* in the encoder pipeline — resource and timing
/// models key off the role, never off hard-coded Fig. 14 ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelRole {
    Gateway,
    LinearQ,
    LinearK,
    LinearV,
    AttnHead(usize),
    SmmHead(usize),
    Proj,
    Ln1,
    /// column-parallel FFN-1 part (hidden x ffn/split)
    Ffn1(usize),
    /// row-parallel FFN-2 part (ffn/split x hidden)
    Ffn2(usize),
    /// GMI Reduce combining the FFN-2 partial sums (only when split > 1)
    FfnReduce,
    Ln2,
    ScatterQ,
    ScatterK,
    ScatterV,
    GatherHeads,
    BcastLn1,
}

impl KernelRole {
    pub fn is_gmi(&self) -> bool {
        matches!(
            self,
            KernelRole::ScatterQ
                | KernelRole::ScatterK
                | KernelRole::ScatterV
                | KernelRole::GatherHeads
                | KernelRole::BcastLn1
                | KernelRole::FfnReduce
        )
    }

    /// Pipeline stage in the paper's layer order (Fig. 14/18): the greedy
    /// seed opens one FPGA per stage when the fleet allows it.
    pub fn stage(&self) -> usize {
        match self {
            KernelRole::Gateway
            | KernelRole::LinearQ
            | KernelRole::LinearK
            | KernelRole::LinearV
            | KernelRole::ScatterQ
            | KernelRole::ScatterK
            | KernelRole::ScatterV => 0,
            KernelRole::AttnHead(_) => 1,
            KernelRole::SmmHead(_) | KernelRole::GatherHeads => 2,
            KernelRole::Proj | KernelRole::Ln1 | KernelRole::BcastLn1 => 3,
            KernelRole::Ffn1(_) => 4,
            KernelRole::Ffn2(_) | KernelRole::FfnReduce | KernelRole::Ln2 => 5,
        }
    }
}

/// Number of pipeline stages (`KernelRole::stage` values).
pub const N_STAGES: usize = 6;

/// Per-edge payload size, resolved against the shape and the actual
/// sequence length at estimation time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeBytes {
    /// one hidden-wide int8 row
    Hidden,
    /// one head segment (hidden / heads)
    HeadDim,
    /// one attention-probability row (m bytes)
    SeqLen,
    /// one FFN-part activation row (ffn / split)
    FfnPart,
    /// one wide residual-domain row (4 bytes per hidden element)
    WideHidden,
}

/// One connection-graph edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelEdge {
    pub src: u8,
    pub dst: u8,
    pub bytes: EdgeBytes,
    /// the destination buffers this entire stream before emitting
    /// anything (the K / V matrices of the attention kernels)
    pub gating: bool,
}

/// One kernel node of the generalized encoder graph.
#[derive(Debug, Clone)]
pub struct KernelNode {
    pub id: u8,
    pub name: String,
    pub role: KernelRole,
}

/// Kernel ids of a shape's encoder graph (contiguous, gateway = 0; for
/// the paper shape these coincide with `ibert::graph::ids`).
#[derive(Debug, Clone, Copy)]
pub struct ShapeIds {
    pub gateway: u8,
    pub linear_q: u8,
    pub linear_k: u8,
    pub linear_v: u8,
    pub attn_base: u8,
    pub smm_base: u8,
    pub proj: u8,
    pub ln1: u8,
    pub ffn1_base: u8,
    pub ffn2_base: u8,
    pub ln2: u8,
    pub scatter_q: u8,
    pub scatter_k: u8,
    pub scatter_v: u8,
    pub gather: u8,
    pub bcast: u8,
    pub reduce: Option<u8>,
    pub n: usize,
}

impl ModelShape {
    pub fn ids(&self) -> ShapeIds {
        let h = self.heads as u8;
        let s = self.ffn_split as u8;
        let ffn1_base = 6 + 2 * h;
        let ffn2_base = ffn1_base + s;
        let ln2 = ffn2_base + s;
        ShapeIds {
            gateway: 0,
            linear_q: 1,
            linear_k: 2,
            linear_v: 3,
            attn_base: 4,
            smm_base: 4 + h,
            proj: 4 + 2 * h,
            ln1: 5 + 2 * h,
            ffn1_base,
            ffn2_base,
            ln2,
            scatter_q: ln2 + 1,
            scatter_k: ln2 + 2,
            scatter_v: ln2 + 3,
            gather: ln2 + 4,
            bcast: ln2 + 5,
            reduce: if s > 1 { Some(ln2 + 6) } else { None },
            n: 12 + 2 * self.heads + 2 * self.ffn_split + usize::from(s > 1),
        }
    }
}

/// The placer's working representation: kernels + edges + shape/PE.
#[derive(Debug, Clone)]
pub struct KernelGraph {
    pub shape: ModelShape,
    pub pe: PeConfig,
    pub nodes: Vec<KernelNode>,
    pub edges: Vec<KernelEdge>,
    /// kernel ids in the paper's layer order (the greedy seed order)
    order: Vec<u8>,
    /// in-edge indices (into `edges`) per kernel id
    in_edge_idx: Vec<Vec<usize>>,
    /// topological order of kernel ids — precomputed so the cost model
    /// can score thousands of candidate placements without re-sorting
    topo: Vec<usize>,
    /// decode mode: the attention/SMM head kernels keep per-head KV
    /// caches resident, charged against BRAM on top of the FIFO model
    decode: bool,
    /// continuous-batching KV slots: in decode mode each head holds
    /// `kv_slots` independent cache regions (one per concurrently
    /// admitted sequence), multiplying the BRAM charge
    kv_slots: u32,
}

impl KernelGraph {
    /// Build the generalized encoder graph for a shape.
    pub fn encoder(shape: ModelShape, pe: PeConfig) -> Result<KernelGraph> {
        shape.validate()?;
        let ids = shape.ids();
        ensure!(ids.n <= 255, "encoder graph exceeds the 256-kernel cluster limit");

        let mut nodes: Vec<Option<KernelNode>> = vec![None; ids.n];
        let mut add = |id: u8, role: KernelRole, name: String| {
            nodes[id as usize] = Some(KernelNode { id, name, role });
        };
        add(ids.gateway, KernelRole::Gateway, "gateway+broadcast".into());
        add(ids.linear_q, KernelRole::LinearQ, "linear-q+quant".into());
        add(ids.linear_k, KernelRole::LinearK, "linear-k+quant".into());
        add(ids.linear_v, KernelRole::LinearV, "linear-v+quant".into());
        for h in 0..shape.heads {
            add(
                ids.attn_base + h as u8,
                KernelRole::AttnHead(h),
                format!("dot-product+softmax-h{h}"),
            );
            add(ids.smm_base + h as u8, KernelRole::SmmHead(h), format!("softmax-mm+quant-h{h}"));
        }
        add(ids.proj, KernelRole::Proj, "linear-proj+quant".into());
        add(ids.ln1, KernelRole::Ln1, "add+layernorm-1".into());
        for p in 0..shape.ffn_split {
            let suffix = if shape.ffn_split > 1 { format!("-p{p}") } else { String::new() };
            add(ids.ffn1_base + p as u8, KernelRole::Ffn1(p), format!("linear-ffn1+gelu{suffix}"));
            add(ids.ffn2_base + p as u8, KernelRole::Ffn2(p), format!("linear-ffn2+quant{suffix}"));
        }
        add(ids.ln2, KernelRole::Ln2, "add+layernorm-2".into());
        add(ids.scatter_q, KernelRole::ScatterQ, "gmi-scatter-q".into());
        add(ids.scatter_k, KernelRole::ScatterK, "gmi-scatter-k".into());
        add(ids.scatter_v, KernelRole::ScatterV, "gmi-scatter-v".into());
        add(ids.gather, KernelRole::GatherHeads, "gmi-gather-heads".into());
        add(ids.bcast, KernelRole::BcastLn1, "gmi-broadcast-ln1".into());
        if let Some(r) = ids.reduce {
            add(r, KernelRole::FfnReduce, "gmi-reduce-ffn2".into());
        }
        let nodes: Vec<KernelNode> = nodes
            .into_iter()
            .enumerate()
            .map(|(i, n)| n.unwrap_or_else(|| panic!("kernel id {i} unassigned")))
            .collect();

        let mut edges = Vec::new();
        let mut e = |src: u8, dst: u8, bytes: EdgeBytes, gating: bool| {
            edges.push(KernelEdge { src, dst, bytes, gating });
        };
        e(ids.gateway, ids.linear_q, EdgeBytes::Hidden, false);
        e(ids.gateway, ids.linear_k, EdgeBytes::Hidden, false);
        e(ids.gateway, ids.linear_v, EdgeBytes::Hidden, false);
        e(ids.gateway, ids.ln1, EdgeBytes::Hidden, false); // residual
        e(ids.linear_q, ids.scatter_q, EdgeBytes::Hidden, false);
        e(ids.linear_k, ids.scatter_k, EdgeBytes::Hidden, false);
        e(ids.linear_v, ids.scatter_v, EdgeBytes::Hidden, false);
        for h in 0..shape.heads as u8 {
            e(ids.scatter_q, ids.attn_base + h, EdgeBytes::HeadDim, false);
            e(ids.scatter_k, ids.attn_base + h, EdgeBytes::HeadDim, true);
            e(ids.scatter_v, ids.smm_base + h, EdgeBytes::HeadDim, true);
            e(ids.attn_base + h, ids.smm_base + h, EdgeBytes::SeqLen, false);
            e(ids.smm_base + h, ids.gather, EdgeBytes::HeadDim, false);
        }
        e(ids.gather, ids.proj, EdgeBytes::Hidden, false);
        e(ids.proj, ids.ln1, EdgeBytes::WideHidden, false);
        e(ids.ln1, ids.bcast, EdgeBytes::Hidden, false);
        for p in 0..shape.ffn_split as u8 {
            e(ids.bcast, ids.ffn1_base + p, EdgeBytes::Hidden, false);
            e(ids.ffn1_base + p, ids.ffn2_base + p, EdgeBytes::FfnPart, false);
        }
        e(ids.bcast, ids.ln2, EdgeBytes::Hidden, false); // residual
        match ids.reduce {
            None => e(ids.ffn2_base, ids.ln2, EdgeBytes::WideHidden, false),
            Some(r) => {
                for p in 0..shape.ffn_split as u8 {
                    e(ids.ffn2_base + p, r, EdgeBytes::WideHidden, false);
                }
                e(r, ids.ln2, EdgeBytes::WideHidden, false);
            }
        }

        // placement order: the paper's layer order within each stage
        let mut order = vec![
            ids.gateway,
            ids.linear_q,
            ids.linear_k,
            ids.linear_v,
            ids.scatter_q,
            ids.scatter_k,
            ids.scatter_v,
        ];
        order.extend((0..shape.heads as u8).map(|h| ids.attn_base + h));
        order.extend((0..shape.heads as u8).map(|h| ids.smm_base + h));
        order.push(ids.gather);
        order.extend([ids.proj, ids.ln1, ids.bcast]);
        order.extend((0..shape.ffn_split as u8).map(|p| ids.ffn1_base + p));
        order.extend((0..shape.ffn_split as u8).map(|p| ids.ffn2_base + p));
        if let Some(r) = ids.reduce {
            order.push(r);
        }
        order.push(ids.ln2);

        // adjacency + topological order (Kahn), computed once
        let n = ids.n;
        let mut indeg = vec![0usize; n];
        let mut out_adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut in_edge_idx: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, edge) in edges.iter().enumerate() {
            indeg[edge.dst as usize] += 1;
            out_adj[edge.src as usize].push(i);
            in_edge_idx[edge.dst as usize].push(i);
        }
        let mut topo: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut head = 0;
        while head < topo.len() {
            let u = topo[head];
            head += 1;
            for &ei in &out_adj[u] {
                let v = edges[ei].dst as usize;
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    topo.push(v);
                }
            }
        }
        ensure!(topo.len() == n, "encoder graph has a cycle");

        Ok(KernelGraph {
            shape,
            pe,
            nodes,
            edges,
            order,
            in_edge_idx,
            topo,
            decode: false,
            kv_slots: 1,
        })
    }

    /// Switch the graph into decode mode: `usage` additionally charges
    /// each attention/SMM head its persistent KV-cache BRAM.
    pub fn with_decode(mut self, decode: bool) -> KernelGraph {
        self.decode = decode;
        self
    }

    pub fn is_decode(&self) -> bool {
        self.decode
    }

    /// Size the decode KV caches for `slots` concurrently batched
    /// sequences (continuous batching admits up to `--batch-max` at
    /// once; each needs its own cache region). No effect outside decode
    /// mode; `slots <= 1` is the single-sequence charge.
    pub fn with_kv_slots(mut self, slots: u32) -> KernelGraph {
        self.kv_slots = slots.max(1);
        self
    }

    pub fn kv_slots(&self) -> u32 {
        self.kv_slots
    }

    pub fn n_kernels(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: u8) -> &KernelNode {
        &self.nodes[id as usize]
    }

    /// Kernel ids in placement (paper layer) order.
    pub fn placement_order(&self) -> &[u8] {
        &self.order
    }

    /// Kernel ids (as indices) in topological order.
    pub fn topo_order(&self) -> &[usize] {
        &self.topo
    }

    /// Indices into `edges` of kernel `id`'s inbound edges.
    pub fn in_edge_indices(&self, id: u8) -> &[usize] {
        &self.in_edge_idx[id as usize]
    }

    /// Kernel ids grouped by pipeline stage, in placement order.
    pub fn stages(&self) -> Vec<Vec<u8>> {
        let mut out = vec![Vec::new(); N_STAGES];
        for &id in &self.order {
            out[self.node(id).role.stage()].push(id);
        }
        out
    }

    /// Payload bytes of one packet on `edge` at sequence length `m`.
    pub fn edge_bytes(&self, edge: &KernelEdge, m: usize) -> usize {
        match edge.bytes {
            EdgeBytes::Hidden => self.shape.hidden,
            EdgeBytes::HeadDim => self.shape.head_dim(),
            EdgeBytes::SeqLen => m,
            EdgeBytes::FfnPart => self.shape.ffn / self.shape.ffn_split,
            EdgeBytes::WideHidden => 4 * self.shape.hidden,
        }
    }

    /// Resource estimate of kernel `id` on a device (FIFOs included; in
    /// decode mode, the role's persistent KV-cache BRAM on top).
    pub fn usage(&self, id: u8, dev: Device) -> ResourceUsage {
        let role = self.node(id).role;
        let mut u = role_usage(role, &self.shape, &self.pe, dev);
        if self.decode {
            let kv = role_kv_bytes(role, &self.shape);
            if kv > 0 {
                u += ResourceUsage {
                    bram18: batched_kv_cache_bram18(kv as u64, self.kv_slots as u64),
                    ..Default::default()
                };
            }
        }
        u
    }
}

// ---------------------------------------------------------------------------
// Role-based resource model (single source of truth; the Fig. 15
// id-based estimator in cluster_builder::layer_builder delegates here)
// ---------------------------------------------------------------------------

/// Input-FIFO capacity of a role, generalizing `ibert::graph::fifo_bytes`
/// (§8.2.1: "large enough to hold at least one matrix").
pub fn role_fifo_in_bytes(role: KernelRole, shape: &ModelShape) -> usize {
    let (m, h, f) = (shape.max_seq, shape.hidden, shape.ffn);
    let d = shape.head_dim();
    match role {
        KernelRole::Gateway => m * h,
        KernelRole::LinearQ | KernelRole::LinearK | KernelRole::LinearV => m * h,
        KernelRole::AttnHead(_) => 2 * m * d,
        KernelRole::SmmHead(_) => m * (m + d),
        KernelRole::Proj => m * h,
        // LN holds the residual matrix while the main path drains
        KernelRole::Ln1 | KernelRole::Ln2 => m * h + 16 * 4 * h,
        KernelRole::Ffn1(_) => m * h,
        KernelRole::Ffn2(_) => m * f / shape.ffn_split,
        KernelRole::FfnReduce => m * 4 * h,
        KernelRole::ScatterQ | KernelRole::ScatterK | KernelRole::ScatterV => 8 * h,
        KernelRole::GatherHeads => m * h,
        KernelRole::BcastLn1 => 8 * h,
    }
}

/// Output-FIFO capacity of a role (one matrix of the output stream).
pub fn role_fifo_out_bytes(role: KernelRole, shape: &ModelShape) -> usize {
    let (m, h, f) = (shape.max_seq, shape.hidden, shape.ffn);
    let d = shape.head_dim();
    match role {
        KernelRole::Gateway => m * h,
        KernelRole::LinearQ | KernelRole::LinearK | KernelRole::LinearV => m * h,
        KernelRole::AttnHead(_) => m * m, // probability rows
        KernelRole::SmmHead(_) => m * d,
        KernelRole::Proj | KernelRole::Ffn2(_) => m * 4 * h, // wide residual rows
        KernelRole::Ffn1(_) => m * f / shape.ffn_split,
        KernelRole::Ln1 | KernelRole::Ln2 => m * h,
        KernelRole::FfnReduce => m * 4 * h,
        KernelRole::ScatterQ
        | KernelRole::ScatterK
        | KernelRole::ScatterV
        | KernelRole::GatherHeads
        | KernelRole::BcastLn1 => 8 * h,
    }
}

/// Persistent KV-cache bytes a role holds on-chip in decode mode: each
/// attention head caches its `[max_seq, head_dim]` K slice, each SMM
/// head the matching V slice. Unlike a FIFO this state lives for a
/// request's whole prefill+decode lifetime, so it is budgeted
/// separately (block-granular, `fpga::resources::kv_cache_bram18`).
pub fn role_kv_bytes(role: KernelRole, shape: &ModelShape) -> usize {
    match role {
        KernelRole::AttnHead(_) | KernelRole::SmmHead(_) => shape.max_seq * shape.head_dim(),
        _ => 0,
    }
}

/// Resource estimate of a role on `dev`: compute base + both FIFOs.
pub fn role_usage(
    role: KernelRole,
    shape: &ModelShape,
    pe: &PeConfig,
    dev: Device,
) -> ResourceUsage {
    use crate::sim::fifo::BRAM18_BYTES;
    let (h, f) = (shape.hidden as u64, shape.ffn as u64);
    let d = shape.head_dim() as u64;
    let m = shape.max_seq as u64;
    let fpart = f / shape.ffn_split as u64;
    let base = match role {
        KernelRole::Gateway => ResourceUsage { lut: 9_000, ff: 14_000, bram18: 8, dsp: 0 },
        KernelRole::LinearQ | KernelRole::LinearK | KernelRole::LinearV | KernelRole::Proj => {
            pe.linear_usage(h, h, pe.linear_macs, dev)
        }
        KernelRole::Ffn1(_) => pe.linear_usage(h, fpart, pe.ffn_macs, dev),
        KernelRole::Ffn2(_) => pe.linear_usage(fpart, h, pe.ffn_macs, dev),
        KernelRole::AttnHead(_) => pe.head_usage(m, d, pe.attn_pes, dev),
        KernelRole::SmmHead(_) => pe.head_usage(m, d, pe.smm_pes, dev),
        KernelRole::Ln1 | KernelRole::Ln2 => pe.pipe_usage(pe.ln_simd),
        KernelRole::ScatterQ
        | KernelRole::ScatterK
        | KernelRole::ScatterV
        | KernelRole::GatherHeads
        | KernelRole::BcastLn1
        | KernelRole::FfnReduce => pe.gmi_usage(),
    };
    let fifo_in = role_fifo_in_bytes(role, shape);
    let fifo_out = role_fifo_out_bytes(role, shape);
    let fifo_bram = (fifo_in.div_ceil(BRAM18_BYTES) + fifo_out.div_ceil(BRAM18_BYTES)) as u64;
    base + ResourceUsage { bram18: fifo_bram, ..Default::default() }
}

/// Role of a Fig. 14 kernel id (the fixed 12-head, split-1 layout of
/// `ibert::graph::ids`). Panics on unknown ids, like the seed estimator.
pub fn fig14_role(id: u8) -> KernelRole {
    use crate::ibert::graph::ids::*;
    match id {
        GATEWAY => KernelRole::Gateway,
        LINEAR_Q => KernelRole::LinearQ,
        LINEAR_K => KernelRole::LinearK,
        LINEAR_V => KernelRole::LinearV,
        x if (ATTN_BASE..ATTN_BASE + 12).contains(&x) => {
            KernelRole::AttnHead((x - ATTN_BASE) as usize)
        }
        x if (SMM_BASE..SMM_BASE + 12).contains(&x) => KernelRole::SmmHead((x - SMM_BASE) as usize),
        PROJ => KernelRole::Proj,
        LN1 => KernelRole::Ln1,
        FFN1 => KernelRole::Ffn1(0),
        FFN2 => KernelRole::Ffn2(0),
        LN2 => KernelRole::Ln2,
        SCATTER_Q => KernelRole::ScatterQ,
        SCATTER_K => KernelRole::ScatterK,
        SCATTER_V => KernelRole::ScatterV,
        GATHER => KernelRole::GatherHeads,
        BCAST_LN1 => KernelRole::BcastLn1,
        _ => panic!("unknown encoder kernel id {id}"),
    }
}

// ---------------------------------------------------------------------------
// Fleet (device catalog + fabric topology)
// ---------------------------------------------------------------------------

/// The FPGAs available to one encoder cluster, in slot order, plus the
/// switch topology they hang off (`sim`'s serially-chained 100G switches).
#[derive(Debug, Clone, PartialEq)]
pub struct Fleet {
    /// device of each FPGA slot — heterogeneous fleets mix entries
    pub devices: Vec<Device>,
    /// FPGAs per top-of-rack switch (Fig. 17: six Sidewinders per switch)
    pub fpgas_per_switch: usize,
    /// utilisation headroom for place-and-route: the packer refuses to
    /// fill any resource beyond this fraction (the paper's own FPGA 5
    /// peaks at ~81% BRAM, so the default leaves a thin margin above it)
    pub util_cap: f64,
}

impl Fleet {
    pub fn homogeneous(dev: Device, n: usize, fpgas_per_switch: usize) -> Fleet {
        Fleet { devices: vec![dev; n], fpgas_per_switch: fpgas_per_switch.max(1), util_cap: 0.85 }
    }

    /// The paper's testbed: six XCZU19EG behind one 100G switch.
    pub fn paper() -> Fleet {
        Fleet::homogeneous(Device::Xczu19eg, 6, 6)
    }

    pub fn with_util_cap(mut self, cap: f64) -> Fleet {
        self.util_cap = cap.clamp(0.1, 1.0);
        self
    }

    pub fn n_slots(&self) -> usize {
        self.devices.len()
    }

    pub fn device(&self, slot: usize) -> Device {
        self.devices[slot]
    }

    pub fn switch_of(&self, slot: usize) -> usize {
        slot / self.fpgas_per_switch
    }

    /// Static per-FPGA overhead: shell ("hypervisor") + routing tables.
    pub fn base_usage(&self, slot: usize) -> ResourceUsage {
        let rt = crate::galapagos::RoutingTables::new(0).bram18() as u64;
        self.device(slot).shell_usage() + ResourceUsage { bram18: rt, ..Default::default() }
    }

    pub fn budget(&self, slot: usize) -> ResourceBudget {
        self.device(slot).budget()
    }

    /// Budget scaled by the utilisation cap (the packer's fit target).
    pub fn capped_budget(&self, slot: usize) -> ResourceBudget {
        let b = self.budget(slot);
        let s = |x: u64| (x as f64 * self.util_cap).floor() as u64;
        ResourceBudget { lut: s(b.lut), ff: s(b.ff), bram18: s(b.bram18), dsp: s(b.dsp) }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.devices.is_empty(), "fleet has no FPGAs");
        ensure!(self.fpgas_per_switch >= 1, "fpgas_per_switch must be positive");
        ensure!(
            self.util_cap > 0.0 && self.util_cap <= 1.0,
            "util_cap must be in (0, 1], got {}",
            self.util_cap
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Placement
// ---------------------------------------------------------------------------

/// A kernel -> FPGA-slot assignment (slot indices are fleet-relative;
/// the Cluster Builder adds each encoder's `fpga_base`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub slot_of: Vec<usize>,
}

impl Placement {
    pub fn n_slots(&self) -> usize {
        self.slot_of.iter().copied().max().map_or(0, |s| s + 1)
    }

    /// Distinct slots actually hosting kernels, ascending.
    pub fn used_slots(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.slot_of.to_vec();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn kernels_on(&self, slot: usize) -> Vec<u8> {
        (0..self.slot_of.len() as u8).filter(|&k| self.slot_of[k as usize] == slot).collect()
    }

    /// The paper's manual Fig. 14 mapping (for the paper shape).
    pub fn fig14() -> Placement {
        Placement {
            slot_of: (0..crate::ibert::graph::KERNELS_PER_ENCODER as u8)
                .map(crate::ibert::graph::fpga_slot)
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Plan: the serializable end-to-end artifact
// ---------------------------------------------------------------------------

/// A complete placement plan: shape + fleet + assignment + prediction.
/// Serializes to JSON so `plan` output can be fed back into `build` /
/// `simulate` (and so placements round-trip through description files).
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub shape: ModelShape,
    pub fleet: Fleet,
    pub placement: Placement,
    pub predicted: LatencyEstimate,
}

impl Plan {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "shape",
                Json::obj(vec![
                    ("hidden", self.shape.hidden.into()),
                    ("ffn", self.shape.ffn.into()),
                    ("heads", self.shape.heads.into()),
                    ("max_seq", self.shape.max_seq.into()),
                    ("ffn_split", self.shape.ffn_split.into()),
                ]),
            ),
            (
                "fleet",
                Json::obj(vec![
                    (
                        "devices",
                        Json::Arr(self.fleet.devices.iter().map(|d| d.name().into()).collect()),
                    ),
                    ("fpgas_per_switch", self.fleet.fpgas_per_switch.into()),
                    ("util_cap", self.fleet.util_cap.into()),
                ]),
            ),
            ("placement", Json::Arr(self.placement.slot_of.iter().map(|&s| s.into()).collect())),
            (
                "predicted",
                Json::obj(vec![
                    ("x_cycles", (self.predicted.x as i64).into()),
                    ("t_cycles", (self.predicted.t as i64).into()),
                    ("i_cycles", (self.predicted.i as i64).into()),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Plan> {
        let geti = |j: &Json, path: &str| -> Result<usize> {
            let v = j
                .path(path)
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow::anyhow!("plan missing integer field {path}"))?;
            ensure!(v >= 0, "plan field {path} must be non-negative, got {v}");
            Ok(v as usize)
        };
        let shape = ModelShape {
            hidden: geti(j, "shape.hidden")?,
            ffn: geti(j, "shape.ffn")?,
            heads: geti(j, "shape.heads")?,
            max_seq: geti(j, "shape.max_seq")?,
            ffn_split: geti(j, "shape.ffn_split")?,
        };
        let devices = j
            .path("fleet.devices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("plan missing fleet.devices"))?
            .iter()
            .map(|d| {
                d.as_str()
                    .and_then(Device::from_name)
                    .ok_or_else(|| anyhow::anyhow!("unknown device in plan: {d}"))
            })
            .collect::<Result<Vec<Device>>>()?;
        let fleet = Fleet {
            devices,
            fpgas_per_switch: geti(j, "fleet.fpgas_per_switch")?,
            util_cap: j
                .path("fleet.util_cap")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("plan missing fleet.util_cap"))?,
        };
        let placement = Placement {
            slot_of: j
                .get("placement")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("plan missing placement"))?
                .iter()
                .map(|s| match s.as_i64() {
                    Some(x) if x >= 0 => Ok(x as usize),
                    _ => Err(anyhow::anyhow!("bad placement slot {s}")),
                })
                .collect::<Result<Vec<usize>>>()?,
        };
        let predicted = LatencyEstimate {
            x: geti(j, "predicted.x_cycles")? as u64,
            t: geti(j, "predicted.t_cycles")? as u64,
            i: geti(j, "predicted.i_cycles")? as u64,
        };
        let plan = Plan { shape, fleet, placement, predicted };
        plan.shape.validate()?;
        plan.fleet.validate()?;
        ensure!(
            plan.placement.slot_of.len() == plan.shape.ids().n,
            "plan placement covers {} kernels, shape has {}",
            plan.placement.slot_of.len(),
            plan.shape.ids().n
        );
        ensure!(
            plan.placement.slot_of.iter().all(|&s| s < plan.fleet.n_slots()),
            "plan placement references a slot outside its fleet"
        );
        Ok(plan)
    }

    pub fn parse(text: &str) -> Result<Plan> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("plan json: {e}"))?;
        Self::from_json(&j)
    }
}

/// Guard rail shared by the CLI and tests: bail early when a graph is
/// structurally impossible to place on a fleet.
pub fn ensure_placeable(graph: &KernelGraph, fleet: &Fleet) -> Result<()> {
    fleet.validate()?;
    for node in &graph.nodes {
        let fits_somewhere = (0..fleet.n_slots()).any(|s| {
            (fleet.base_usage(s) + graph.usage(node.id, fleet.device(s)))
                .fits(&fleet.capped_budget(s))
        });
        if !fits_somewhere {
            bail!(
                "kernel {} ({}) does not fit any fleet device even alone \
                 (consider a larger device or a higher ffn_split)",
                node.id,
                node.name
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_ids_match_fig14() {
        let ids = ModelShape::ibert_base().ids();
        use crate::ibert::graph::ids as fig;
        assert_eq!(ids.n, crate::ibert::graph::KERNELS_PER_ENCODER);
        assert_eq!(ids.proj, fig::PROJ);
        assert_eq!(ids.ln1, fig::LN1);
        assert_eq!(ids.ffn1_base, fig::FFN1);
        assert_eq!(ids.ffn2_base, fig::FFN2);
        assert_eq!(ids.ln2, fig::LN2);
        assert_eq!(ids.scatter_q, fig::SCATTER_Q);
        assert_eq!(ids.scatter_k, fig::SCATTER_K);
        assert_eq!(ids.scatter_v, fig::SCATTER_V);
        assert_eq!(ids.gather, fig::GATHER);
        assert_eq!(ids.bcast, fig::BCAST_LN1);
        assert_eq!(ids.reduce, None);
    }

    #[test]
    fn paper_graph_matches_seed_fifo_model() {
        // the role-based FIFO sizing must agree with the independent
        // id-based implementation in ibert::graph (§8.2.1 sizing rule)
        let shape = ModelShape::ibert_base();
        let g = KernelGraph::encoder(shape, PeConfig::default()).unwrap();
        for id in 0..g.n_kernels() as u8 {
            let role = fig14_role(id);
            assert_eq!(g.node(id).role, role, "role mismatch for kernel {id}");
            assert_eq!(
                role_fifo_in_bytes(role, &shape),
                crate::ibert::graph::fifo_bytes(id, 128, 768, 3072),
                "input FIFO sizing diverged for kernel {id}"
            );
        }
        // output-FIFO sizing against independent literals (the deleted
        // seed implementation's values, so regressions can't hide behind
        // the kernel_usage -> role_usage delegation)
        for (role, want) in [
            (KernelRole::LinearQ, 128 * 768),
            (KernelRole::AttnHead(0), 128 * 128),
            (KernelRole::SmmHead(3), 128 * 64),
            (KernelRole::Proj, 128 * 4 * 768), // wide residual rows
            (KernelRole::Ffn1(0), 128 * 3072),
            (KernelRole::Ffn2(0), 128 * 4 * 768),
            (KernelRole::Ln1, 128 * 768),
            (KernelRole::ScatterQ, 8 * 768),
            (KernelRole::GatherHeads, 8 * 768),
        ] {
            assert_eq!(role_fifo_out_bytes(role, &shape), want, "output FIFO for {role:?}");
        }
    }

    #[test]
    fn decode_mode_charges_kv_cache_bram_on_head_kernels_only() {
        let shape = ModelShape::ibert_base();
        let g = KernelGraph::encoder(shape, PeConfig::default()).unwrap();
        let gd = g.clone().with_decode(true);
        assert!(gd.is_decode());
        let ids = shape.ids();
        let dev = Device::Xczu19eg;
        // one head's K (or V) cache: 128 x 64 bytes -> 4 BRAM18 extra
        let kv = role_kv_bytes(KernelRole::AttnHead(0), &shape);
        assert_eq!(kv, 128 * 64);
        let extra = kv_cache_bram18(kv as u64);
        for h in 0..shape.heads as u8 {
            for base in [ids.attn_base, ids.smm_base] {
                let plain = g.usage(base + h, dev);
                let dec = gd.usage(base + h, dev);
                assert_eq!(dec.bram18, plain.bram18 + extra);
                assert_eq!((dec.lut, dec.ff, dec.dsp), (plain.lut, plain.ff, plain.dsp));
            }
        }
        // everything else is untouched (no cache, no charge)
        for id in [ids.gateway, ids.linear_q, ids.proj, ids.ln1, ids.ffn1_base, ids.ln2, ids.bcast]
        {
            assert_eq!(g.usage(id, dev), gd.usage(id, dev));
        }
        // the fpga-layer BRAM18 geometry must not drift from the sim's
        assert_eq!(kv_cache_bram18(crate::sim::fifo::BRAM18_BYTES as u64), 1);
        assert_eq!(kv_cache_bram18(crate::sim::fifo::BRAM18_BYTES as u64 + 1), 2);
    }

    #[test]
    fn batching_slots_multiply_the_kv_charge() {
        let shape = ModelShape::ibert_base();
        let g = KernelGraph::encoder(shape, PeConfig::default()).unwrap();
        let gd = g.clone().with_decode(true);
        let gb = g.clone().with_decode(true).with_kv_slots(8);
        assert_eq!(gb.kv_slots(), 8);
        let ids = shape.ids();
        let dev = Device::Xczu19eg;
        let one = kv_cache_bram18(role_kv_bytes(KernelRole::AttnHead(0), &shape) as u64);
        for h in 0..shape.heads as u8 {
            for base in [ids.attn_base, ids.smm_base] {
                let plain = gd.usage(base + h, dev);
                let slotted = gb.usage(base + h, dev);
                assert_eq!(slotted.bram18, plain.bram18 + 7 * one, "8 slots = 8x the region");
                assert_eq!(
                    (slotted.lut, slotted.ff, slotted.dsp),
                    (plain.lut, plain.ff, plain.dsp)
                );
            }
        }
        // cache-free kernels never pay for slots, and slots without
        // decode are inert
        assert_eq!(gd.usage(ids.ln1, dev), gb.usage(ids.ln1, dev));
        let inert = g.clone().with_kv_slots(8);
        assert_eq!(inert.usage(ids.attn_base, dev), g.usage(ids.attn_base, dev));
        // the paper build still fits a device with 8-way batching: 24
        // head kernels x 4 BRAM x 8 slots is well under the XCZU19EG
        let per_head = gb.usage(ids.attn_base, dev);
        assert!(per_head.bram18 < dev.budget().bram18 / 4);
    }

    #[test]
    fn split_ffn_graph_is_well_formed() {
        let shape = ModelShape::bert_large().with_ffn_split(2);
        let g = KernelGraph::encoder(shape, PeConfig::default()).unwrap();
        assert_eq!(g.n_kernels(), 12 + 2 * 16 + 2 * 2 + 1);
        let reduce = shape.ids().reduce.unwrap();
        assert_eq!(g.node(reduce).role, KernelRole::FfnReduce);
        // both FFN2 parts feed the reduce, which feeds LN2
        let into_reduce = g.edges.iter().filter(|e| e.dst == reduce).count();
        assert_eq!(into_reduce, 2);
        assert!(g.edges.iter().any(|e| e.src == reduce && e.dst == shape.ids().ln2));
        // every kernel appears exactly once in placement order
        let mut order = g.placement_order().to_vec();
        order.sort_unstable();
        assert_eq!(order, (0..g.n_kernels() as u8).collect::<Vec<_>>());
    }

    #[test]
    fn shape_validation_rejects_bad_shapes() {
        let mut s = ModelShape::ibert_base();
        s.heads = 7; // 768 % 7 != 0
        assert!(s.validate().is_err());
        let mut s = ModelShape::ibert_base();
        s.ffn_split = 5; // 3072 % 5 != 0
        assert!(s.validate().is_err());
        assert!(ModelShape::bert_large().validate().is_ok());
    }

    #[test]
    fn plan_json_roundtrip() {
        let plan = Plan {
            shape: ModelShape::ibert_base(),
            fleet: Fleet::paper(),
            placement: Placement::fig14(),
            predicted: LatencyEstimate { x: 100_000, t: 200_000, i: 767 },
        };
        let text = plan.to_json().pretty();
        let back = Plan::parse(&text).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn fleet_capped_budget_scales() {
        let f = Fleet::paper().with_util_cap(0.5);
        let b = f.budget(0);
        let c = f.capped_budget(0);
        assert_eq!(c.bram18, b.bram18 / 2);
        assert!(c.lut < b.lut);
    }
}
