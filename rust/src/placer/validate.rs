//! Placement validation: completeness, per-device resource fit, flow
//! into the Cluster Builder, and simulator replay for paper-shaped
//! graphs (the end-to-end cross-check of the cost model).

use anyhow::{bail, ensure, Result};

use super::{Fleet, KernelGraph, Placement};
use crate::fpga::resources::{Device, ResourceBudget, ResourceUsage};

/// Aggregate usage of one fleet slot under a placement (shell + routing
/// tables + kernels), checked against the device's FULL budget — the
/// utilisation cap is a packing target, not a validity condition.
#[derive(Debug, Clone)]
pub struct SlotReport {
    pub slot: usize,
    pub device: Device,
    pub kernels: Vec<u8>,
    pub usage: ResourceUsage,
    pub budget: ResourceBudget,
}

impl SlotReport {
    pub fn utilisation(&self) -> (f64, f64, f64, f64) {
        self.usage.utilisation(&self.budget)
    }
    pub fn fits(&self) -> bool {
        self.usage.fits(&self.budget)
    }
}

/// Check a placement end to end: every kernel assigned exactly once to a
/// real fleet slot, and every occupied slot within its device budget.
/// Returns the per-slot reports on success.
pub fn check(g: &KernelGraph, p: &Placement, fleet: &Fleet) -> Result<Vec<SlotReport>> {
    ensure!(
        p.slot_of.len() == g.n_kernels(),
        "placement covers {} kernels, graph has {}",
        p.slot_of.len(),
        g.n_kernels()
    );
    for (k, &s) in p.slot_of.iter().enumerate() {
        ensure!(s < fleet.n_slots(), "kernel {k} assigned to slot {s} outside the fleet");
    }
    let mut reports = Vec::new();
    for slot in p.used_slots() {
        let kernels = p.kernels_on(slot);
        let mut usage = fleet.base_usage(slot);
        for &k in &kernels {
            usage += g.usage(k, fleet.device(slot));
        }
        let r = SlotReport {
            slot,
            device: fleet.device(slot),
            kernels,
            usage,
            budget: fleet.budget(slot),
        };
        if !r.fits() {
            let (l, f, b, d) = r.utilisation();
            bail!(
                "slot {} ({:?}) over budget: LUT {:.0}% FF {:.0}% BRAM {:.0}% DSP {:.0}%",
                r.slot,
                r.device,
                l * 100.0,
                f * 100.0,
                b * 100.0,
                d * 100.0
            );
        }
        reports.push(r);
    }
    Ok(reports)
}

/// Lower a paper-shaped placement into a Cluster Builder encoder build
/// (ClusterSpec + behaviors). Only the Fig. 14-compatible shape has HLS
/// kernels behind it; other shapes are placement-only for now.
pub fn to_encoder_build(
    g: &KernelGraph,
    p: &Placement,
    gp: &crate::ibert::graph::EncoderGraphParams,
) -> Result<crate::ibert::graph::EncoderBuild> {
    ensure!(
        g.shape.is_paper_shape(),
        "only the paper shape (hidden=768, ffn=3072, 12 heads) lowers to the I-BERT build"
    );
    ensure!(p.slot_of.len() == crate::ibert::graph::KERNELS_PER_ENCODER, "bad placement length");
    Ok(crate::ibert::graph::build_encoder_placed(gp, &p.slot_of))
}

/// Replay a paper-shaped placement through the discrete-event simulator
/// at sequence length `m`; returns the measured (X, T, I) at the
/// evaluation sink. This is the ground truth the cost model is checked
/// against (`galapagos-llm plan --replay`).
pub fn replay_in_simulator(
    g: &KernelGraph,
    p: &Placement,
    fleet: &Fleet,
    m: usize,
) -> Result<(u64, u64, u64)> {
    ensure!(
        g.shape.is_paper_shape() && g.shape.max_seq <= 128,
        "simulator replay supports only the paper shape (the six-FPGA I-BERT build)"
    );
    ensure!(m >= 1 && m <= g.shape.max_seq, "m out of range");
    check(g, p, fleet)?;
    let mut cfg = crate::eval::testbed::TestbedConfig::proof_of_concept(
        m,
        crate::ibert::kernels::Mode::Timing,
    );
    cfg.pe = g.pe;
    cfg.fpgas_per_switch = fleet.fpgas_per_switch;
    cfg.placement = Some(p.slot_of.clone());
    let r = crate::eval::testbed::run_encoder_once(&cfg)?;
    Ok((r.x, r.t, r.i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ibert::timing::PeConfig;
    use crate::placer::ModelShape;

    fn paper() -> (KernelGraph, Placement, Fleet) {
        let g = KernelGraph::encoder(ModelShape::ibert_base(), PeConfig::default()).unwrap();
        (g, Placement::fig14(), Fleet::paper())
    }

    #[test]
    fn fig14_placement_checks_clean() {
        let (g, p, f) = paper();
        let reports = check(&g, &p, &f).unwrap();
        assert_eq!(reports.len(), 6);
        assert!(reports.iter().all(|r| r.fits()));
        // same aggregate picture as the seed Fig. 15 reports
        let total: usize = reports.iter().map(|r| r.kernels.len()).sum();
        assert_eq!(total, 38);
    }

    #[test]
    fn incomplete_or_oversubscribed_placements_rejected() {
        let (g, mut p, f) = paper();
        p.slot_of.pop();
        assert!(check(&g, &p, &f).is_err(), "short placement must fail");
        let (g, mut p, f) = paper();
        p.slot_of[0] = 99;
        assert!(check(&g, &p, &f).is_err(), "out-of-fleet slot must fail");
        let (g, p, _) = paper();
        // cram everything onto one FPGA: BRAM blows the real budget
        let one = Placement { slot_of: vec![0; p.slot_of.len()] };
        let f1 = Fleet::paper();
        assert!(check(&g, &one, &f1).is_err(), "single-FPGA I-BERT must be over budget");
    }
}
