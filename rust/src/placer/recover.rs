//! Incremental re-placement after an FPGA failure — the recovery half of
//! the §6 operational story.
//!
//! When one FPGA of a cluster dies, §6 says only that cluster is
//! re-configured. The full placer (`search::place`) would happily redraw
//! the whole mapping, but reconfiguring FPGAs that did not fail would
//! wipe their in-flight state and widen the blast radius — so recovery
//! uses a *minimal-perturbation* mode instead: every kernel on a
//! surviving FPGA stays exactly where it is, and only the displaced
//! kernels (those that lived on the failed slot) are re-packed onto the
//! survivors, cheapest-latency-first under the cost model.
//!
//! A fleet that was sized for the full mapping often cannot absorb a
//! whole FPGA's worth of kernels under the utilisation cap; recovery
//! then degrades gracefully instead of refusing: first it relaxes the
//! cap to the full device budget, and as a last resort it overcommits
//! the least-loaded slot and flags the solution `degraded` — the
//! platform keeps serving at reduced headroom until the failed board is
//! replaced, and the serving report says so honestly.
//!
//! [`ReconfigModel`] supplies the recovery latency: the §6 outage is the
//! time to stream a full configuration image onto the replacement
//! region, during which inbound packets buffer in the cluster input
//! buffer (see `sim::engine::FailurePlan`).

use anyhow::{ensure, Result};

use super::cost::{estimate, LatencyEstimate};
use super::{Fleet, KernelGraph, Placement};
use crate::fpga::resources::{Device, ResourceUsage};
use crate::FABRIC_CLOCK_HZ;

/// Reconfiguration-latency model: a full configuration image streamed at
/// the configuration port's sustained rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReconfigModel {
    pub bitstream_bytes: u64,
    /// sustained configuration bandwidth in MB/s (ICAP over PCIe-class
    /// delivery; JTAG would be ~1000x slower)
    pub config_mbps: u64,
}

impl ReconfigModel {
    pub fn for_device(dev: Device) -> ReconfigModel {
        ReconfigModel { bitstream_bytes: dev.bitstream_bytes(), config_mbps: 400 }
    }

    /// Outage duration in fabric cycles (never 0 — the engine requires a
    /// positive recovery window).
    pub fn cycles(&self) -> u64 {
        let secs = self.bitstream_bytes as f64 / (self.config_mbps.max(1) as f64 * 1e6);
        ((secs * FABRIC_CLOCK_HZ as f64).round() as u64).max(1)
    }
}

/// One kernel the recovery moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Move {
    pub kernel: u8,
    pub from: usize,
    pub to: usize,
}

impl Move {
    /// The same move expressed in global fleet slots. A tenant's recovery
    /// runs against its own sub-fleet (`placer::multi`), so the
    /// fleet-level view adds the sub-fleet's base offset — keeping the
    /// re-place itself provably ignorant of every other tenant's slots.
    pub fn offset(self, base: usize) -> Move {
        Move { kernel: self.kernel, from: self.from + base, to: self.to + base }
    }
}

/// A recovery placement for one failed slot.
#[derive(Debug, Clone)]
pub struct RecoverySolution {
    /// the full post-recovery mapping (surviving kernels untouched)
    pub placement: Placement,
    /// displaced kernels and where they went, in placement order
    pub moved: Vec<Move>,
    /// true when the survivors could not absorb the displaced kernels
    /// within their full device budgets — the fleet is overcommitted
    /// until the failed board is replaced
    pub degraded: bool,
    /// cost-model prediction for the degraded mapping
    pub predicted: LatencyEstimate,
}

/// Re-place the kernels of `failed_slot` onto the surviving slots of
/// `fleet`, leaving every other kernel of `base` untouched. `m` is the
/// sequence length the cost model scores candidate targets at.
pub fn replace_after_failure(
    graph: &KernelGraph,
    base: &Placement,
    fleet: &Fleet,
    failed_slot: usize,
    m: usize,
) -> Result<RecoverySolution> {
    fleet.validate()?;
    ensure!(failed_slot < fleet.n_slots(), "failed slot {failed_slot} outside the fleet");
    ensure!(
        base.slot_of.len() == graph.n_kernels(),
        "placement covers {} kernels, graph has {}",
        base.slot_of.len(),
        graph.n_kernels()
    );
    ensure!(fleet.n_slots() >= 2, "cannot recover: the fleet has no surviving FPGA");
    let m = m.clamp(1, graph.shape.max_seq);

    // survivors' load with the displaced kernels removed
    let n_slots = fleet.n_slots();
    let mut used: Vec<ResourceUsage> = (0..n_slots).map(|s| fleet.base_usage(s)).collect();
    for (k, &s) in base.slot_of.iter().enumerate() {
        if s != failed_slot {
            used[s] += graph.usage(k as u8, fleet.device(s));
        }
    }

    let displaced: Vec<u8> = graph
        .placement_order()
        .iter()
        .copied()
        .filter(|&k| base.slot_of[k as usize] == failed_slot)
        .collect();
    ensure!(!displaced.is_empty(), "slot {failed_slot} hosts no kernels of this placement");

    let mut placement = base.clone();
    let mut moved = Vec::with_capacity(displaced.len());
    let mut degraded = false;

    for &k in &displaced {
        let need = |s: usize| used[s] + graph.usage(k, fleet.device(s));
        // candidate tiers: capped budget, then full budget, then (last
        // resort) the least-overcommitted slot — never the failed one
        let survivors = (0..n_slots).filter(|&s| s != failed_slot);
        let capped: Vec<usize> =
            survivors.clone().filter(|&s| need(s).fits(&fleet.capped_budget(s))).collect();
        let full: Vec<usize> =
            survivors.clone().filter(|&s| need(s).fits(&fleet.budget(s))).collect();
        let (cands, tier_degraded) = if !capped.is_empty() {
            (capped, false)
        } else if !full.is_empty() {
            (full, false)
        } else {
            // overcommit: pick the slot that ends up least utilised
            let s = survivors
                .min_by(|&a, &b| {
                    let ua = need(a).max_utilisation(&fleet.budget(a));
                    let ub = need(b).max_utilisation(&fleet.budget(b));
                    ua.partial_cmp(&ub).expect("utilisations are finite")
                })
                .expect("fleet has at least one survivor");
            (vec![s], true)
        };
        degraded |= tier_degraded;

        // among the feasible targets, take the cheapest by predicted T
        // (the earliest slot on ties — deterministic)
        let mut best: Option<(usize, u64)> = None;
        for &s in &cands {
            placement.slot_of[k as usize] = s;
            if let Ok(e) = estimate(graph, &placement, fleet, m, 12) {
                if best.is_none_or(|(_, c)| e.t < c) {
                    best = Some((s, e.t));
                }
            }
        }
        let (to, _) = best.unwrap_or((cands[0], 0));
        placement.slot_of[k as usize] = to;
        used[to] += graph.usage(k, fleet.device(to));
        moved.push(Move { kernel: k, from: failed_slot, to });
    }

    let predicted = estimate(graph, &placement, fleet, m, 12)?;
    Ok(RecoverySolution { placement, moved, degraded, predicted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::Device;
    use crate::ibert::timing::PeConfig;
    use crate::placer::{ModelShape, SearchParams};

    fn paper_graph() -> KernelGraph {
        KernelGraph::encoder(ModelShape::ibert_base(), PeConfig::default()).unwrap()
    }

    #[test]
    fn reconfig_model_is_in_the_hundred_ms_range() {
        let c = ReconfigModel::for_device(Device::Xczu19eg).cycles();
        let ms = c as f64 / FABRIC_CLOCK_HZ as f64 * 1e3;
        assert!((50.0..500.0).contains(&ms), "XCZU19EG reconfiguration ~= {ms:.0} ms");
        assert!(
            ReconfigModel::for_device(Device::Xcvc1902).cycles() > c,
            "the larger Versal image takes longer to load"
        );
        assert!(ReconfigModel { bitstream_bytes: 0, config_mbps: 400 }.cycles() >= 1);
    }

    #[test]
    fn recovery_moves_only_the_displaced_kernels() {
        let g = paper_graph();
        let base = Placement::fig14();
        let fleet = Fleet::paper();
        let failed = 2; // the attention FPGA
        let rec = replace_after_failure(&g, &base, &fleet, failed, 128).unwrap();
        for (k, (&old, &new)) in
            base.slot_of.iter().zip(rec.placement.slot_of.iter()).enumerate()
        {
            if old == failed {
                assert_ne!(new, failed, "kernel {k} must leave the failed slot");
            } else {
                assert_eq!(new, old, "surviving kernel {k} must not move (§6 isolation)");
            }
        }
        assert_eq!(
            rec.moved.len(),
            base.slot_of.iter().filter(|&&s| s == failed).count(),
            "every displaced kernel accounted for"
        );
        assert!(rec.moved.iter().all(|m| m.from == failed && m.to != failed));
    }

    #[test]
    fn paper_fleet_recovery_is_degraded_but_complete() {
        // six XCZU19EG were sized for six stages; losing one forces the
        // survivors to overcommit — recovery must still produce a full
        // mapping and say so via the degraded flag rather than refuse
        let g = paper_graph();
        let base = Placement::fig14();
        let fleet = Fleet::paper();
        for failed in 0..6 {
            let rec = replace_after_failure(&g, &base, &fleet, failed, 128).unwrap();
            assert!(rec.placement.slot_of.iter().all(|&s| s != failed));
            assert!(rec.predicted.t > 0);
        }
    }

    #[test]
    fn roomy_fleet_recovers_without_degradation() {
        // with spare FPGAs the displaced kernels fit under the cap
        let fleet = Fleet::homogeneous(Device::Xczu19eg, 9, 6);
        let sol = crate::placer::place(
            &ModelShape::ibert_base(),
            &PeConfig::default(),
            &fleet,
            &SearchParams::default(),
        )
        .unwrap();
        let failed = sol.placement.slot_of[crate::ibert::graph::ids::ATTN_BASE as usize];
        let rec =
            replace_after_failure(&sol.graph, &sol.placement, &fleet, failed, 128).unwrap();
        assert!(!rec.degraded, "a 9-slot fleet has room for one FPGA's kernels");
        crate::placer::validate::check(&sol.graph, &rec.placement, &fleet).unwrap();
    }

    #[test]
    fn move_offset_shifts_both_slots() {
        let m = Move { kernel: 7, from: 2, to: 4 };
        assert_eq!(m.offset(10), Move { kernel: 7, from: 12, to: 14 });
        assert_eq!(m.offset(0), m);
    }

    #[test]
    fn rejects_nonsense_inputs() {
        let g = paper_graph();
        let base = Placement::fig14();
        let fleet = Fleet::paper();
        assert!(replace_after_failure(&g, &base, &fleet, 99, 128).is_err());
        let one = Fleet::homogeneous(Device::Xczu19eg, 1, 6);
        let tiny = Placement { slot_of: vec![0; g.n_kernels()] };
        assert!(replace_after_failure(&g, &tiny, &one, 0, 128).is_err());
    }
}
