//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO *text*: jax >= 0.5 serialises HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py).

use std::path::Path;

use anyhow::{Context, Result};

/// The PJRT client (one per process).
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
}

/// One compiled executable.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<LoadedModule> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?;
        Ok(LoadedModule { exe })
    }
}

impl LoadedModule {
    /// Execute with the given inputs; returns the untupled outputs.
    /// (aot.py lowers with return_tuple=True, so there is always a tuple.)
    /// Accepts owned literals or references (resident weights stay put).
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<L>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Build an int8 2-D literal [m, n] from row vectors.
pub fn lit_i8_2d(rows: &[Vec<i8>]) -> Result<xla::Literal> {
    let m = rows.len();
    let n = rows.first().map(|r| r.len()).unwrap_or(0);
    let mut bytes = Vec::with_capacity(m * n);
    for r in rows {
        anyhow::ensure!(r.len() == n, "ragged rows");
        bytes.extend(r.iter().map(|&v| v as u8));
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        &[m, n],
        &bytes,
    )?)
}

/// Build an int32 1-D literal.
pub fn lit_i32_1d(v: &[i32]) -> Result<xla::Literal> {
    let mut bytes = Vec::with_capacity(4 * v.len());
    for x in v {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[v.len()],
        &bytes,
    )?)
}

/// Build an int64 1-D literal.
pub fn lit_i64_1d(v: &[i64]) -> Result<xla::Literal> {
    let mut bytes = Vec::with_capacity(8 * v.len());
    for x in v {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S64,
        &[v.len()],
        &bytes,
    )?)
}

/// Build a GTF tensor literal (weights from the model file system).
pub fn lit_from_tensor(t: &crate::util::tensorfile::Tensor) -> Result<xla::Literal> {
    use crate::util::tensorfile::Tensor;
    let dims = t.dims().to_vec();
    Ok(match t {
        Tensor::I8(td) => {
            let bytes: Vec<u8> = td.data.iter().map(|&v| v as u8).collect();
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, &dims, &bytes)?
        }
        Tensor::I32(td) => {
            let mut bytes = Vec::with_capacity(4 * td.data.len());
            for x in &td.data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, &dims, &bytes)?
        }
        Tensor::I64(td) => {
            let mut bytes = Vec::with_capacity(8 * td.data.len());
            for x in &td.data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S64, &dims, &bytes)?
        }
        Tensor::F32(td) => {
            let mut bytes = Vec::with_capacity(4 * td.data.len());
            for x in &td.data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &dims, &bytes)?
        }
    })
}

/// Extract an int8 matrix [m, n] from a literal.
pub fn rows_from_lit_i8(lit: &xla::Literal, m: usize, n: usize) -> Result<Vec<Vec<i8>>> {
    let flat: Vec<i8> = lit.to_vec()?;
    anyhow::ensure!(flat.len() == m * n, "literal size {} != {}x{}", flat.len(), m, n);
    Ok(flat.chunks(n).map(|c| c.to_vec()).collect())
}
