//! PJRT runtime: load the AOT HLO artifacts (python/compile/aot.py) and
//! execute them on the request path. Python never runs at serve time.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{EncoderEngine, Manifest};
pub use pjrt::{lit_i32_1d, lit_i8_2d, LoadedModule, PjrtRuntime};
