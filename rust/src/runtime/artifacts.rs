//! Artifact manifest + the encoder serving engine.
//!
//! The AOT calling convention (python/compile/aot.py lower_encoder):
//!   param 0: x int8[m, H], param 1: mask int32[m],
//!   params 2..: the 16 weight arrays in EncoderParams.weight_arrays order.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::pjrt::{lit_from_tensor, lit_i32_1d, lit_i8_2d, rows_from_lit_i8, LoadedModule, PjrtRuntime};
use crate::ibert::ModelParams;
use crate::util::json::Json;
use crate::util::tensorfile::read_tensor;

/// Parsed artifacts/manifest.json.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub json: Json,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {dir:?}/manifest.json — run `make artifacts`"))?;
        Ok(Manifest { dir, json: Json::parse(&text).context("manifest.json")? })
    }

    pub fn artifact_file(&self, name: &str) -> Result<PathBuf> {
        match self.json.path(&format!("artifacts.{name}.file")).and_then(Json::as_str) {
            Some(f) => Ok(self.dir.join(f)),
            None => bail!("artifact {name} not in manifest"),
        }
    }

    /// Ordered weight parameter names of an artifact (skipping x and mask).
    pub fn weight_param_names(&self, name: &str) -> Result<Vec<String>> {
        let params = self
            .json
            .path(&format!("artifacts.{name}.params"))
            .and_then(|p| p.as_arr())
            .with_context(|| format!("artifact {name} params"))?;
        Ok(params
            .iter()
            .filter_map(|p| p.as_arr().and_then(|t| t.first()).and_then(Json::as_str))
            .filter(|n| *n != "x" && *n != "mask" && *n != "w" && *n != "b")
            .map(|s| s.to_string())
            .collect())
    }

    pub fn max_seq(&self) -> usize {
        self.json.get("max_seq").and_then(Json::as_i64).unwrap_or(128) as usize
    }
}

/// The serving engine: a compiled encoder executable plus resident weight
/// literals — the request-path object (no Python anywhere).
pub struct EncoderEngine {
    module: LoadedModule,
    weights: Vec<xla::Literal>,
    pub m: usize,
    pub hidden: usize,
    pub num_encoders: usize,
}

impl EncoderEngine {
    /// Load manifest + HLO + weights and compile (one-time cost).
    pub fn load(rt: &PjrtRuntime, dir: impl AsRef<Path>) -> Result<EncoderEngine> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let params = ModelParams::load(dir)?;
        let module = rt.load_hlo_text(manifest.artifact_file("encoder_m128")?)?;

        let mut weights = Vec::new();
        for name in manifest.weight_param_names("encoder_m128")? {
            let wpath = match manifest.json.path(&format!("weights.{name}.file")).and_then(Json::as_str)
            {
                Some(f) => dir.join(f),
                None => bail!("weight {name} not in manifest"),
            };
            weights.push(lit_from_tensor(&read_tensor(wpath)?)?);
        }
        anyhow::ensure!(weights.len() == 16, "expected 16 weight params, got {}", weights.len());

        Ok(EncoderEngine {
            module,
            weights,
            m: manifest.max_seq(),
            hidden: params.cfg.hidden,
            num_encoders: params.cfg.num_encoders,
        })
    }

    /// Run one encoder over `x` (actual length rows). Pads to the
    /// artifact's fixed shape, masks the padded key columns, slices back —
    /// bit-identical to the no-padding hardware path (tested).
    pub fn infer(&self, x: &[Vec<i8>]) -> Result<Vec<Vec<i8>>> {
        let m = x.len();
        anyhow::ensure!(m >= 1 && m <= self.m, "sequence length {m} out of range 1..={}", self.m);
        let mut padded = x.to_vec();
        padded.resize(self.m, vec![0i8; self.hidden]);
        let mut mask = vec![0i32; self.m];
        for v in mask.iter_mut().take(m) {
            *v = 1;
        }

        // weights stay resident; only x and mask are fresh per request
        let x_lit = lit_i8_2d(&padded)?;
        let mask_lit = lit_i32_1d(&mask)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(2 + self.weights.len());
        inputs.push(&x_lit);
        inputs.push(&mask_lit);
        inputs.extend(self.weights.iter());
        let out = self.module.execute(&inputs)?;
        anyhow::ensure!(!out.is_empty(), "encoder artifact returned nothing");
        let full = rows_from_lit_i8(&out[0], self.m, self.hidden)?;
        Ok(full[..m].to_vec())
    }

    /// Run the full model: `n` chained encoders (weight-shared, like the
    /// paper's estimate).
    pub fn infer_model(&self, x: &[Vec<i8>], n: usize) -> Result<Vec<Vec<i8>>> {
        let mut cur = x.to_vec();
        for _ in 0..n {
            cur = self.infer(&cur)?;
        }
        Ok(cur)
    }
}

