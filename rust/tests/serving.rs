//! Serving-path tests: Eq. 1 validated against the fully simulated
//! N-encoder pipeline, seed determinism of serving results, and the
//! open-loop queueing behavior of the request source.
//!
//! Everything here runs in Timing mode — no artifacts required.

use std::sync::Arc;

use galapagos_llm::eval::testbed::{
    build_testbed, inter_encoder_hop_cycles, run_encoder_once, TestbedConfig,
};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::serve::{
    run_serving, validate_eq1, validate_serving_report, ArrivalProcess, DecodeConfig, LengthDist,
    Request, ServeConfig,
};
use galapagos_llm::sim::ShardGranularity;
use galapagos_llm::util::quickcheck::{check_with, Config};

/// The headline claim of this repo's serving subsystem: the paper's
/// Eq. 1 extrapolation `T + (L-1)(X + d)` agrees with an actually
/// simulated N-encoder pipeline within 5%, for every chain depth the
/// paper discusses (1 = PoC, 12 = full I-BERT) and for both the GLUE
/// mean length and the full build point.
#[test]
fn eq1_matches_simulated_pipeline_within_5pct() {
    let base = TestbedConfig::proof_of_concept(38, Mode::Timing);
    for &m in &[38usize, 128] {
        for &n in &[1usize, 2, 6, 12] {
            let e = validate_eq1(&base, n, m).unwrap();
            let err = e.rel_err();
            assert!(
                err.abs() < 0.05,
                "Eq. 1 off by {:+.2}% at encoders={n}, m={m} \
                 (analytic {} vs simulated {})",
                100.0 * err,
                e.analytic,
                e.simulated
            );
            if n == 1 {
                // no extrapolation at L=1: the estimate IS the measured T
                assert_eq!(e.analytic, e.simulated);
            }
        }
    }
}

#[test]
fn inter_encoder_hop_is_the_papers_d() {
    // Fig. 17 layout: six FPGAs per encoder, six per switch => every
    // encoder-to-encoder edge crosses exactly one serial switch hop,
    // which is the d = 1.1 us = 220 cycles of Eq. 1
    let cfg = TestbedConfig::proof_of_concept(38, Mode::Timing);
    for boundary in 0..11 {
        assert_eq!(inter_encoder_hop_cycles(&cfg, boundary), 220);
    }
    // cramming 13 FPGAs onto one switch removes the hop entirely
    let mut dense = cfg.clone();
    dense.fpgas_per_switch = 13;
    assert_eq!(inter_encoder_hop_cycles(&dense, 0), 0);
    // when the switch width does not divide the encoder width, the hop
    // count varies by boundary: 4/switch puts LN2 of encoder 0 (FPGA 5)
    // and the gateway of encoder 1 (FPGA 6) on the same switch, but LN2
    // of encoder 1 (FPGA 11) and the gateway of encoder 2 (FPGA 12) a
    // full hop apart — the Eq. 1 check must sum per-boundary d
    let mut uneven = cfg.clone();
    uneven.fpgas_per_switch = 4;
    assert_eq!(inter_encoder_hop_cycles(&uneven, 0), 0);
    assert_eq!(inter_encoder_hop_cycles(&uneven, 1), 220);
}

/// At near-zero load every request sees an idle pipeline, so its
/// serving latency must equal the single-shot latency of its own length
/// EXACTLY — time-shift invariance of the DES, via the serving source.
#[test]
fn unloaded_serving_latency_equals_single_shot_latency() {
    let gap = 10_000_000u64; // far beyond any drain time
    let lens = [16u32, 38, 64];
    let schedule: Vec<Request> = lens
        .iter()
        .enumerate()
        .map(|(i, &m)| Request { arrival: i as u64 * gap, m })
        .collect();
    let mut cfg = TestbedConfig::proof_of_concept(128, Mode::Timing);
    cfg.schedule = Some(Arc::new(schedule.clone()));
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    let sink = tb.sink.lock().unwrap();
    for (i, req) in schedule.iter().enumerate() {
        let &(pkts, done) = sink.arrivals.get(&(i as u32)).unwrap();
        assert_eq!(pkts, req.m, "request {i} incomplete");
        let single =
            run_encoder_once(&TestbedConfig::proof_of_concept(req.m as usize, Mode::Timing))
                .unwrap();
        assert_eq!(
            done - req.arrival,
            single.t,
            "request {i} (m={}) latency != single-shot T",
            req.m
        );
    }
}

#[test]
fn zero_length_requests_rejected() {
    // a 0-row request could never complete (the source's row counter
    // would pump forever); the builder must refuse it up front
    let mut cfg = TestbedConfig::proof_of_concept(128, Mode::Timing);
    cfg.schedule = Some(Arc::new(vec![Request { arrival: 0, m: 0 }]));
    assert!(build_testbed(&cfg).is_err());
}

#[test]
fn serving_is_seed_deterministic() {
    let cfg = ServeConfig::glue(2, 24, 3_000.0, 42);
    let a = run_serving(&cfg).unwrap();
    let b = run_serving(&cfg).unwrap();
    assert_eq!(a.latencies, b.latencies, "same seed must reproduce verbatim");
    assert_eq!(a.to_json().pretty(), b.to_json().pretty());

    let mut other = cfg.clone();
    other.traffic.seed = 43;
    let c = run_serving(&other).unwrap();
    assert_ne!(a.latencies, c.latencies, "different seed must differ");
}

/// Determinism holds across randomly drawn scenarios, not just one.
#[test]
fn serving_determinism_property() {
    let cfg = Config { cases: 4, base_seed: 0x5E27E, max_size: 16 };
    check_with(&cfg, "serving runs are reproducible", |g| {
        let seed = g.rng.next_u64();
        let rate = 500.0 + g.f64_unit() * 4_000.0;
        let n = g.usize_in(4, 10);
        let encoders = g.usize_in(1, 3);
        let mut sc = ServeConfig::glue(encoders, n, rate, seed);
        if g.bool() {
            sc.traffic.process = ArrivalProcess::Uniform { seqs_per_s: rate };
        }
        if g.bool() {
            sc.traffic.lengths = LengthDist::Mrpc;
        }
        let a = run_serving(&sc).map_err(|e| e.to_string())?;
        let b = run_serving(&sc).map_err(|e| e.to_string())?;
        if a.latencies != b.latencies {
            return Err(format!("latencies diverged for seed {seed:#x}"));
        }
        if a.completed != sc.traffic.requests {
            return Err(format!("{}/{} requests completed", a.completed, sc.traffic.requests));
        }
        Ok(())
    });
}

/// Open-loop overload: offering far more than the pipeline sustains
/// must show up as queueing — tail latency grows and the first stage
/// saturates — while an under-loaded run stays near single-shot latency.
#[test]
fn overload_grows_tail_latency_and_backpressure() {
    let requests = 40;
    // capacity at m~38 is roughly FABRIC_CLOCK / (T - X) ~ thousands of
    // seqs/s; 400 seqs/s is a light load, 40_000 is far beyond capacity
    let light = run_serving(&ServeConfig::glue(2, requests, 400.0, 9)).unwrap();
    let heavy = run_serving(&ServeConfig::glue(2, requests, 40_000.0, 9)).unwrap();
    assert_eq!(light.completed, requests);
    assert_eq!(heavy.completed, requests, "open-loop: every request still completes");
    assert!(
        heavy.latency.p99 > 2 * light.latency.p99,
        "overload p99 {} should dwarf light-load p99 {}",
        heavy.latency.p99,
        light.latency.p99
    );
    // open-loop backlog grows roughly linearly in request index, so the
    // tail sits well above the median (but below 2x: p99/p50 ~ 39/20)
    assert!(
        2 * heavy.latency.p99 > 3 * heavy.latency.p50.max(1),
        "overload must skew the tail (p50 {} p99 {})",
        heavy.latency.p50,
        heavy.latency.p99
    );
    // Little's law separates the regimes: the saturated run holds many
    // requests in flight, the light one well under one
    assert!(
        heavy.mean_inflight() > 2.0 * light.mean_inflight().max(1e-6),
        "overload in-flight {:.3} vs light {:.3}",
        heavy.mean_inflight(),
        light.mean_inflight()
    );
    // and the backlog parks in real FIFOs (LN1 holds residual matrices
    // while the attention path drains): the high-water mark must rise
    assert!(
        heavy.stages[0].fifo_peak > light.stages[0].fifo_peak,
        "backlog should raise the FIFO high-water ({} vs {})",
        heavy.stages[0].fifo_peak,
        light.stages[0].fifo_peak
    );
    assert!(heavy.stages.iter().all(|s| s.occupancy > 0.0 && s.occupancy <= 1.0));
}

/// The sharded parallel engine's serving contract: the full
/// serving_report JSON — latencies, percentiles, stage occupancy, FIFO
/// high-water marks, event counts — is bit-identical at every thread
/// count (this is also what the CI thread-matrix job diffs).
#[test]
fn parallel_serving_reports_are_bit_identical() {
    let mut cfg = ServeConfig::glue(3, 18, 3_000.0, 11);
    cfg.check_eq1 = true;
    cfg.threads = Some(1);
    let seq = run_serving(&cfg).unwrap();
    for threads in [2usize, 4, 8] {
        cfg.threads = Some(threads);
        let par = run_serving(&cfg).unwrap();
        assert_eq!(seq.latencies, par.latencies, "latencies diverged at threads={threads}");
        assert_eq!(
            seq.to_json().pretty(),
            par.to_json().pretty(),
            "serving_report JSON diverged at threads={threads}"
        );
    }
}

/// Shard-boundary burst splitting: a line-rate schedule forms long
/// intra-FPGA row bursts that split exactly at the encoder boundary —
/// the cross-shard edge of the parallel engine. Sink arrivals and
/// per-request completions must match the sequential engine row for row
/// (and the pre-coalescing reference engine, closing the loop).
#[test]
fn shard_boundary_burst_split_is_cycle_exact() {
    // back-to-back arrivals at line rate: maximal burst formation
    let schedule: Vec<Request> = (0..6)
        .map(|i| Request { arrival: i * 100, m: 32 })
        .collect();
    let run = |threads: Option<usize>, reference: bool| {
        let mut cfg = TestbedConfig::proof_of_concept(128, Mode::Timing);
        cfg.encoders = 2;
        cfg.schedule = Some(Arc::new(schedule.clone()));
        cfg.threads = threads;
        let mut tb = build_testbed(&cfg).unwrap();
        if reference {
            tb.sim.reference_mode();
        }
        tb.sim.start();
        tb.sim.run().unwrap();
        let probes = tb.sim.trace.probe_times(tb.sink_id).unwrap().to_vec();
        let sink = tb.sink.lock().unwrap();
        let done: Vec<(u32, u64)> =
            (0..6).filter_map(|i| sink.arrivals.get(&i).map(|&(p, t)| (p, t))).collect();
        (probes, done, tb.sim.time)
    };
    let seq = run(Some(1), false);
    let par = run(Some(8), false);
    let reference = run(Some(1), true);
    assert_eq!(par, seq, "parallel burst-split diverged from sequential");
    assert_eq!(reference, seq, "coalesced engines diverged from the reference engine");
    assert_eq!(seq.0.len(), 6 * 32, "every row of every request reached the sink");
}

/// Backward compatibility: serving reports committed by earlier PRs must
/// keep validating as the schema grows. The fixtures are real v2/v3/v4/v5
/// report skeletons; the v6-aware validator must accept all untouched.
#[test]
fn committed_fixture_reports_still_validate() {
    for (name, text) in [
        ("v2", include_str!("fixtures/serving_report_v2.json")),
        ("v3", include_str!("fixtures/serving_report_v3.json")),
        ("v4", include_str!("fixtures/serving_report_v4.json")),
        ("v5", include_str!("fixtures/serving_report_v5.json")),
    ] {
        let j = galapagos_llm::util::json::Json::parse(text)
            .unwrap_or_else(|e| panic!("{name} fixture unparseable: {e}"));
        validate_serving_report(&j)
            .unwrap_or_else(|e| panic!("{name} fixture rejected by the v6 validator: {e}"));
        assert_eq!(
            j.get("schema").unwrap().as_str().unwrap(),
            format!("serving_report/{name}"),
            "fixture {name} carries the wrong schema tag"
        );
    }
}

/// End-to-end v4 round trip: a real decode run serializes as v4,
/// validates, parses back, and still validates with the decode metrics
/// intact.
#[test]
fn decode_serving_report_round_trips_as_v4() {
    let mut cfg = ServeConfig::glue(2, 8, 2_500.0, 21);
    cfg.decode = Some(DecodeConfig { max_new_tokens: 2 });
    let r = run_serving(&cfg).unwrap();
    assert_eq!(r.completed, 8);
    assert_eq!(r.schema(), "serving_report/v4");
    let j = r.to_json();
    validate_serving_report(&j).unwrap();
    let back = galapagos_llm::util::json::Json::parse(&j.pretty()).unwrap();
    validate_serving_report(&back).unwrap();
    assert_eq!(back.path("decode.max_new_tokens").unwrap().as_i64().unwrap(), 2);
    assert_eq!(back.path("decode.generated_tokens").unwrap().as_i64().unwrap(), 16);
    assert_eq!(back.path("decode.kv_occupancy").unwrap().as_arr().unwrap().len(), 8);
    assert!(back.path("decode.ttft.p50_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert!(back.path("decode.itl.p50_cycles").unwrap().as_f64().unwrap() > 0.0);
}

/// The crown-jewel contract extended to generation: decode serving
/// reports — TTFT/ITL percentiles, KV occupancy, per-request latencies —
/// are bit-identical at every thread count and shard granularity.
#[test]
fn parallel_decode_serving_reports_are_bit_identical() {
    let mut cfg = ServeConfig::glue(2, 10, 2_500.0, 17);
    cfg.decode = Some(DecodeConfig { max_new_tokens: 3 });
    cfg.threads = Some(1);
    let seq = run_serving(&cfg).unwrap();
    assert_eq!(seq.completed, 10);
    for (threads, granularity) in [
        (2usize, ShardGranularity::PerCluster),
        (4, ShardGranularity::PerFpga),
        (8, ShardGranularity::PerCluster),
        (8, ShardGranularity::PerFpga),
    ] {
        cfg.threads = Some(threads);
        cfg.granularity = Some(granularity);
        let par = run_serving(&cfg).unwrap();
        assert_eq!(seq.latencies, par.latencies, "latencies diverged at threads={threads}");
        assert_eq!(
            seq.to_json().pretty(),
            par.to_json().pretty(),
            "decode serving_report diverged at threads={threads}"
        );
    }
}

#[test]
fn squad_traffic_serves_on_the_128_token_build() {
    let mut cfg = ServeConfig::glue(2, 16, 1_500.0, 5);
    cfg.traffic.lengths = LengthDist::Squad; // mean 152, max 384: clamps to 128
    let r = run_serving(&cfg).unwrap();
    assert_eq!(r.completed, 16);
    assert_eq!(r.workload, "squad");
    // clamped long-context requests actually hit the build point
    assert!(r.total_tokens >= 16 * 50, "squad tokens unexpectedly low");
}

#[test]
fn six_encoder_glue_pipeline_reports_full_metrics() {
    // the acceptance scenario: >= 6 encoders under streaming GLUE traffic
    let mut cfg = ServeConfig::glue(6, 30, 2_500.0, 7);
    cfg.check_eq1 = true;
    let r = run_serving(&cfg).unwrap();
    assert_eq!(r.completed, 30);
    assert_eq!(r.stages.len(), 6);
    assert!(r.latency.p50 <= r.latency.p95 && r.latency.p95 <= r.latency.p99);
    assert!(r.seqs_per_s() > 0.0);
    // every stage ingested every row of every request
    assert!(r.stages.iter().all(|s| s.rows_in == r.total_tokens));
    // deeper stages finish later, so occupancy is meaningful everywhere
    assert!(r.stages.iter().all(|s| s.occupancy > 0.0 && s.occupancy <= 1.0));
    let e = r.eq1.expect("eq1 check requested");
    assert!(e.rel_err().abs() < 0.05, "Eq. 1 off by {:+.2}%", 100.0 * e.rel_err());
}
