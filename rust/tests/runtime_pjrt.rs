//! PJRT runtime integration: the AOT artifacts (lowered from the Pallas
//! path) executed from rust must agree bit-exactly with the goldens and
//! with the native rust compute — closing the L1/L2/L3 loop.

use galapagos_llm::ibert::encoder::{encoder_forward, rows_i8};
use galapagos_llm::ibert::weights::{load_golden, ModelParams};
use galapagos_llm::runtime::{EncoderEngine, PjrtRuntime};

fn artifacts() -> std::path::PathBuf {
    let d = ModelParams::default_dir();
    assert!(d.join("manifest.json").exists(), "run `make artifacts` first");
    d
}

#[test]
fn smoke_artifact_runs() {
    let dir = artifacts();
    let rt = PjrtRuntime::cpu().unwrap();
    let module = rt.load_hlo_text(dir.join("smoke.hlo.txt")).unwrap();
    // smoke: pallas matmul_int8 of 2x2 int8
    let x = galapagos_llm::runtime::lit_i8_2d(&[vec![1, 2], vec![3, 4]]).unwrap();
    let w = galapagos_llm::runtime::lit_i8_2d(&[vec![1, 0], vec![0, 1]]).unwrap();
    let out = module.execute(&[&x, &w]).unwrap();
    let v: Vec<i32> = out[0].to_vec().unwrap();
    assert_eq!(v, vec![1, 2, 3, 4], "identity matmul through the pallas artifact");
}

#[test]
fn encoder_engine_matches_goldens_and_native() {
    let dir = artifacts();
    let rt = PjrtRuntime::cpu().unwrap();
    let engine = EncoderEngine::load(&rt, &dir).unwrap();
    let p = ModelParams::load(&dir).unwrap();
    let x128 = rows_i8(load_golden(&dir, "input_m128").unwrap().as_i8().unwrap());

    for m in [1usize, 38, 128] {
        let got = engine.infer(&x128[..m]).unwrap();
        let golden = rows_i8(
            load_golden(&dir, &format!("encoder_out_m{m}")).unwrap().as_i8().unwrap(),
        );
        assert_eq!(got, golden, "PJRT encoder != golden at m={m}");
        let native = encoder_forward(&p, &x128[..m]).out;
        assert_eq!(got, native, "PJRT encoder != native rust at m={m}");
    }
}

#[test]
fn encoder_engine_model12() {
    let dir = artifacts();
    let rt = PjrtRuntime::cpu().unwrap();
    let engine = EncoderEngine::load(&rt, &dir).unwrap();
    let x128 = rows_i8(load_golden(&dir, "input_m128").unwrap().as_i8().unwrap());
    let got = engine.infer_model(&x128[..38], 12).unwrap();
    let golden = rows_i8(load_golden(&dir, "model12_out_m38").unwrap().as_i8().unwrap());
    assert_eq!(got, golden, "PJRT 12-encoder model != golden");
}
