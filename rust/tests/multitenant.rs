//! Multi-tenant serving integration tests (PR 10): the shipped 3-tenant
//! config end to end, thread/shard bit-identity of mixed-shape rosters,
//! SLO-aware admission visible in the v6 report, and the
//! failure-isolation contract — an FPGA dying inside one tenant's chain
//! leaves the bystander tenant's report section *byte-identical*.
//!
//! Everything here runs in Timing mode — no artifacts required.

use galapagos_llm::eval::testbed::FailureSchedule;
use galapagos_llm::serve::{
    run_multi_tenant_serving, run_serving, validate_serving_report, ArrivalProcess, LengthDist,
    MultiTenantConfig, ServeConfig, ServingReport, TenantClass, TenantSpec, TenantsConfig,
};
use galapagos_llm::sim::ShardGranularity;

/// The config file the CLI ships (`serve --tenants configs/tenants_3.json`)
/// — tests and CI exercise the exact bytes users start from.
const TENANTS_3: &str = include_str!("../../configs/tenants_3.json");

fn two_tenants() -> TenantsConfig {
    TenantsConfig {
        interval: 12,
        fpgas_per_switch: 6,
        tenants: vec![
            TenantSpec {
                name: "victim".into(),
                encoders: 2,
                class: TenantClass::Guaranteed,
                slo_p99_us: 900.0,
                kv_slots: 8,
                requests: 8,
                process: ArrivalProcess::Poisson { seqs_per_s: 2_000.0 },
                lengths: LengthDist::Glue,
                max_m: 64,
            },
            TenantSpec {
                name: "bystander".into(),
                encoders: 1,
                class: TenantClass::BestEffort,
                slo_p99_us: 2_000.0,
                kv_slots: 16,
                requests: 6,
                process: ArrivalProcess::Uniform { seqs_per_s: 4_000.0 },
                lengths: LengthDist::Mrpc,
                max_m: 32,
            },
        ],
    }
}

/// One tenant's section of the serialized report, as the exact bytes the
/// `--out` file would carry.
fn tenant_section(r: &ServingReport, i: usize) -> String {
    r.to_json().get("tenants").unwrap().as_arr().unwrap()[i].pretty()
}

/// The shipped 3-tenant config (two model shapes, both SLO classes)
/// places via the multi-tenant placer, serves a mixed schedule, and
/// emits a valid `serving_report/v6`.
#[test]
fn shipped_three_tenant_config_serves_end_to_end() {
    let tc = TenantsConfig::parse(TENANTS_3).expect("shipped config must parse");
    assert_eq!(tc.tenants.len(), 3);
    let r = run_multi_tenant_serving(&MultiTenantConfig::new(tc, 7)).unwrap();
    assert_eq!(r.schema(), "serving_report/v6");
    validate_serving_report(&r.to_json()).unwrap();

    let ts = r.tenants.as_ref().unwrap();
    let names: Vec<&str> = ts.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, ["chat", "embed", "batch"]);
    // mixed chain depths (three distinct shapes) and both SLO classes
    let depths: Vec<usize> = ts.iter().map(|t| t.encoders).collect();
    assert_eq!(depths, [3, 1, 2]);
    assert!(ts.iter().any(|t| t.class == "guaranteed"));
    assert!(ts.iter().any(|t| t.class == "best-effort"));
    for t in ts {
        assert_eq!(t.offered, t.admitted + t.rejected_slo + t.rejected_kv);
        assert_eq!(t.completed, t.admitted, "{}: light load completes fully", t.name);
        assert!(t.latency.p99 >= t.latency.p50 && t.ttft.p50 > 0);
        assert!(t.makespan_cycles > 0 && t.seqs_per_s() > 0.0);
    }
    // the aggregate view is the per-tenant view summed
    assert_eq!(r.requests as u64, ts.iter().map(|t| t.admitted).sum::<u64>());
    assert_eq!(r.completed as u64, ts.iter().map(|t| t.completed).sum::<u64>());
    assert_eq!(r.encoders, 6, "3 + 1 + 2 encoder clusters on one fleet");
    assert_eq!(r.stages.len(), 6);
    assert_eq!(r.workload, "glue+mrpc+squad");
    assert_eq!(r.process, "poisson+poisson+uniform");
    let f = r.fairness.as_ref().unwrap();
    assert!((f.jain_index - 1.0).abs() < 1e-9, "everyone fully served -> jain 1.0");
    let rendered = r.render();
    for name in names {
        assert!(rendered.contains(name), "report render must show tenant {name:?}");
    }
}

/// The determinism contract extends to mixed-shape tenant rosters:
/// the full v6 report is byte-identical at 1 vs 8 threads, on both
/// shard granularities. (CI re-checks this through the CLI.)
#[test]
fn three_tenant_report_is_thread_and_shard_invariant() {
    let tc = TenantsConfig::parse(TENANTS_3).unwrap();
    let mut cfg = MultiTenantConfig::new(tc, 19);
    cfg.threads = Some(1);
    let seq = run_multi_tenant_serving(&cfg).unwrap();
    for g in [ShardGranularity::PerCluster, ShardGranularity::PerFpga] {
        cfg.threads = Some(8);
        cfg.granularity = Some(g);
        let par = run_multi_tenant_serving(&cfg).unwrap();
        assert_eq!(seq.to_json().pretty(), par.to_json().pretty(), "diverged under {g:?}");
    }
}

/// SLO-aware admission is visible end to end: a best-effort tenant
/// offering ~200x its chain's ingest rate gets load shed at admission
/// (counted per tenant in the report), while the guaranteed sibling's
/// admission is untouched. The serving layer then completes exactly the
/// admitted subset.
#[test]
fn overload_is_shed_at_admission_and_counted_per_tenant() {
    let tc = TenantsConfig {
        interval: 12,
        fpgas_per_switch: 6,
        tenants: vec![
            TenantSpec {
                name: "chat".into(),
                encoders: 1,
                class: TenantClass::Guaranteed,
                slo_p99_us: 900.0,
                kv_slots: 8,
                requests: 6,
                process: ArrivalProcess::Poisson { seqs_per_s: 2_000.0 },
                lengths: LengthDist::Glue,
                max_m: 32,
            },
            TenantSpec {
                name: "firehose".into(),
                encoders: 1,
                class: TenantClass::BestEffort,
                slo_p99_us: 100.0,
                kv_slots: 4,
                requests: 48,
                process: ArrivalProcess::Poisson { seqs_per_s: 1_000_000.0 },
                lengths: LengthDist::Mrpc,
                max_m: 32,
            },
        ],
    };
    let r = run_multi_tenant_serving(&MultiTenantConfig::new(tc, 13)).unwrap();
    validate_serving_report(&r.to_json()).unwrap();
    let ts = r.tenants.as_ref().unwrap();
    let (chat, firehose) = (&ts[0], &ts[1]);
    assert_eq!(chat.rejected_slo + chat.rejected_kv, 0, "guaranteed tenant sheds nothing");
    assert_eq!(chat.completed, chat.admitted);
    assert!(
        firehose.rejected_slo + firehose.rejected_kv > 0,
        "a 1M seqs/s firehose against 4 KV slots must shed load at admission"
    );
    assert_eq!(firehose.offered, 48);
    assert_eq!(firehose.offered, firehose.admitted + firehose.rejected_slo + firehose.rejected_kv);
    assert_eq!(firehose.completed, firehose.admitted, "everything admitted completes");
    assert!(firehose.reject_rate() > 0.0 && firehose.delivered_fraction() < 1.0);
    // rejects skew fairness away from 1.0 — the section records it
    let f = r.fairness.as_ref().unwrap();
    assert!(f.jain_index < 1.0);
    // the rejected load never entered the fabric: the aggregate request
    // count is the admitted total, not the offered total
    assert_eq!(r.requests as u64, chat.admitted + firehose.admitted);
}

/// THE failure-isolation contract (ISSUE satellite): an FPGA dying
/// mid-serving inside one tenant's chain, with per-tenant-minimal
/// recovery, leaves the OTHER tenant's report section byte-identical to
/// the no-failure run of the same topology. Sources are open-loop and
/// everything downstream of the shared ingress NIC is per-tenant, so a
/// neighbor's outage cannot move a bystander's timeline.
#[test]
fn fpga_failure_leaves_bystander_tenant_byte_identical() {
    let seed = 29;
    let baseline = {
        let cfg = MultiTenantConfig::new(two_tenants(), seed);
        run_multi_tenant_serving(&cfg).unwrap()
    };
    let failed = {
        let mut cfg = MultiTenantConfig::new(two_tenants(), seed);
        // global FPGA 0 is always inside tenant 0's chain; kill it while
        // the victim's first requests are mid-flight
        cfg.fail = Some(FailureSchedule {
            fpga: 0,
            at_cycle: 2_000,
            recovery_cycles: Some(60_000),
        });
        run_multi_tenant_serving(&cfg).unwrap()
    };

    // the failure really happened, on the victim's board, and recovered
    let fault = failed.fault.as_ref().expect("fault section must be present");
    assert_eq!(fault.fpga, 0);
    assert!(fault.recovered, "outage must recover within the run");
    assert!(baseline.fault.is_none());

    // the victim's own section moved (held packets, recovery window)...
    assert_ne!(
        tenant_section(&baseline, 0),
        tenant_section(&failed, 0),
        "the victim tenant must feel its own FPGA dying"
    );
    // ...but the bystander's section is byte-for-byte the same
    assert_eq!(
        tenant_section(&baseline, 1),
        tenant_section(&failed, 1),
        "a neighbor's FPGA failure leaked into the bystander tenant's report"
    );
    let bystander = &failed.tenants.as_ref().unwrap()[1];
    assert_eq!(bystander.completed, bystander.admitted);

    // failure runs keep the thread/shard bit-identity contract too
    let mut cfg = MultiTenantConfig::new(two_tenants(), seed);
    cfg.fail = Some(FailureSchedule { fpga: 0, at_cycle: 2_000, recovery_cycles: Some(60_000) });
    cfg.threads = Some(8);
    cfg.granularity = Some(ShardGranularity::PerFpga);
    let par = run_multi_tenant_serving(&cfg).unwrap();
    assert_eq!(failed.to_json().pretty(), par.to_json().pretty());
}

/// A failure scheduled on an FPGA outside every tenant's chain is
/// refused up front, naming the problem (the eval FPGA has its own
/// guard inside the testbed — it is the measurement harness).
#[test]
fn failing_an_fpga_outside_every_chain_is_rejected() {
    let mut cfg = MultiTenantConfig::new(two_tenants(), 3);
    cfg.fail = Some(FailureSchedule { fpga: 10_000, at_cycle: 100, recovery_cycles: None });
    let err = run_multi_tenant_serving(&cfg).unwrap_err().to_string();
    assert!(err.contains("hosts no kernels"), "{err}");
}

/// With `--tenants` off nothing changes: the single-tenant serving path
/// still emits pre-v6 reports with no tenants/fairness sections, so
/// committed v5-era artifacts stay byte-compatible.
#[test]
fn single_tenant_path_emits_no_tenant_sections() {
    let r = run_serving(&ServeConfig::glue(1, 4, 2_000.0, 5)).unwrap();
    assert_ne!(r.schema(), "serving_report/v6");
    let j = r.to_json();
    validate_serving_report(&j).unwrap();
    assert!(j.get("tenants").is_none(), "non-tenant runs must not grow a tenants section");
    assert!(j.get("fairness").is_none(), "non-tenant runs must not grow a fairness section");
}
