//! End-to-end simulation tests: the six-FPGA encoder cluster produces
//! bit-exact I-BERT output (functional mode) and paper-shaped timing.

use std::sync::Arc;

use galapagos_llm::eval::testbed::{build_testbed, run_encoder_once, TestbedConfig};
use galapagos_llm::ibert::encoder::{encoder_forward, model_forward, rows_i8};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::weights::{load_golden, ModelParams};

fn artifacts() -> std::path::PathBuf {
    let d = ModelParams::default_dir();
    assert!(d.join("quantparams.json").exists(), "run `make artifacts` first");
    d
}

fn golden_input(dir: &std::path::Path, m: usize) -> Vec<Vec<i8>> {
    let x = rows_i8(load_golden(dir, "input_m128").unwrap().as_i8().unwrap());
    x[..m].to_vec()
}

#[test]
fn functional_sim_is_bit_exact_m38() {
    let dir = artifacts();
    let p = Arc::new(ModelParams::load(&dir).unwrap());
    let m = 38;
    let input = golden_input(&dir, m);
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(p.clone()));
    cfg.input = Some(Arc::new(input.clone()));
    let run = run_encoder_once(&cfg).unwrap();
    let got =
        run.testbed.sink.lock().unwrap().matrix(0).expect("sink did not assemble the output");
    let want = encoder_forward(&p, &input).out;
    assert_eq!(got, want, "simulated six-FPGA encoder != reference");
}

#[test]
fn functional_sim_pipelines_multiple_inferences() {
    let dir = artifacts();
    let p = Arc::new(ModelParams::load(&dir).unwrap());
    let m = 16;
    let input = golden_input(&dir, m);
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(p.clone()));
    cfg.inferences = 3;
    cfg.input = Some(Arc::new(input.clone()));
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    let want = encoder_forward(&p, &input).out;
    let sink = tb.sink.lock().unwrap();
    for inf in 0..3 {
        let got = sink.matrix(inf).unwrap_or_else(|| panic!("inference {inf} incomplete"));
        assert_eq!(got, want, "inference {inf} mismatch");
    }
}

#[test]
fn two_encoder_chain_is_bit_exact() {
    let dir = artifacts();
    let p = Arc::new(ModelParams::load(&dir).unwrap());
    let m = 8;
    let input = golden_input(&dir, m);
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(p.clone()));
    cfg.encoders = 2;
    cfg.input = Some(Arc::new(input.clone()));
    let run = run_encoder_once(&cfg).unwrap();
    let got = run.testbed.sink.lock().unwrap().matrix(0).unwrap();
    let want = model_forward(&p, &input, 2);
    assert_eq!(got, want, "two chained encoder clusters != reference");
}

#[test]
fn timing_shape_matches_paper_m128() {
    // Table 1 anchors: I ~ 767..800, T ~ 2x layer-0 (~200-240k), X/T ~ 0.5
    let cfg = TestbedConfig::proof_of_concept(128, Mode::Timing);
    let run = run_encoder_once(&cfg).unwrap();
    let (x, t, i) = (run.x, run.t, run.i);
    assert!(run.end_cycle >= t, "quiescence cannot precede the last output");
    assert!(
        (760..=820).contains(&i),
        "output interval I should be ~767+-eps, got {i}"
    );
    assert!(
        (190_000..=260_000).contains(&t),
        "encoder total T should be ~210k cycles, got {t}"
    );
    let ratio = x as f64 / t as f64;
    assert!(
        (0.4..=0.65).contains(&ratio),
        "X/T should be ~0.53 (paper), got {ratio:.3} (x={x}, t={t})"
    );
}

#[test]
fn timing_mode_agrees_with_functional_mode() {
    // padding-free timing must not depend on payload contents
    let dir = artifacts();
    let p = Arc::new(ModelParams::load(&dir).unwrap());
    let m = 16;
    let t = run_encoder_once(&TestbedConfig::proof_of_concept(m, Mode::Timing)).unwrap();
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(p.clone()));
    cfg.input = Some(Arc::new(golden_input(&dir, m)));
    let f = run_encoder_once(&cfg).unwrap();
    assert_eq!((t.x, t.t, t.i), (f.x, f.t, f.i), "timing must be payload-independent");
}

#[test]
fn no_padding_latency_scales_with_m() {
    // Fig. 16's shape: latency grows with sequence length, and short
    // sequences are much cheaper than the padded maximum.
    let mut prev_t = 0;
    let mut t128 = 0;
    let mut t16 = 0;
    for m in [16usize, 32, 64, 128] {
        let t = run_encoder_once(&TestbedConfig::proof_of_concept(m, Mode::Timing)).unwrap().t;
        assert!(t > prev_t, "T must grow with m (m={m}: {t} <= {prev_t})");
        prev_t = t;
        if m == 128 {
            t128 = t;
        }
        if m == 16 {
            t16 = t;
        }
    }
    assert!(t16 * 3 < t128, "no-padding short sequences must be much cheaper");
}
