//! Cycle-domain telemetry: the observability subsystem's contracts.
//!
//! * Exact shard-merge: the Chrome trace, the metrics JSONL stream and
//!   the `serving_report/v3` JSON are byte-identical at every
//!   `--threads` count — including lossy and failure-injection runs,
//!   which now execute on the sharded engine like everything else.
//! * Zero perturbation: enabling telemetry never changes what the
//!   simulation computes, and a telemetry-off report serializes as the
//!   pre-telemetry `serving_report/v2`, byte for byte.
//! * The previously dead `KernelStats::wakes` counter is surfaced in
//!   per-kernel telemetry and aggregated in the report.

use galapagos_llm::eval::testbed::FailureSchedule;
use galapagos_llm::serve::{
    run_serving, run_serving_with_obs, validate_serving_report, ArrivalProcess, ObsOutput,
    ServeConfig, ServingReport,
};
use galapagos_llm::util::json::Json;

fn obs_cfg(threads: usize) -> ServeConfig {
    let mut cfg = ServeConfig::glue(3, 12, 3_000.0, 11);
    cfg.threads = Some(threads);
    cfg.obs.enabled = true;
    cfg
}

fn artifacts(cfg: &ServeConfig) -> (ServingReport, String, String) {
    let (r, obs) = run_serving_with_obs(cfg).unwrap();
    let ObsOutput { trace_json, metrics_jsonl } = obs;
    (r, trace_json.expect("telemetry on"), metrics_jsonl.expect("telemetry on"))
}

/// The tentpole acceptance: trace + metrics + report bit-identical at
/// threads {1, 2, 8} on a clean multi-encoder serving run.
#[test]
fn telemetry_artifacts_are_thread_count_invariant() {
    let (r1, trace1, metrics1) = artifacts(&obs_cfg(1));
    let golden = r1.to_json().pretty();
    assert_eq!(r1.schema(), "serving_report/v3");
    for threads in [2usize, 8] {
        let (r, trace, metrics) = artifacts(&obs_cfg(threads));
        assert_eq!(trace, trace1, "Chrome trace diverged at threads={threads}");
        assert_eq!(metrics, metrics1, "metrics stream diverged at threads={threads}");
        assert_eq!(r.to_json().pretty(), golden, "v3 report diverged at threads={threads}");
    }
}

/// Lossy (reliable) and failure-injection runs force the sequential
/// engine at every thread count — their telemetry must come out
/// byte-identical too.
#[test]
fn degraded_mode_telemetry_is_thread_count_invariant() {
    let lossy = |threads: usize| {
        let mut cfg = obs_cfg(threads);
        cfg.drop_probability = 0.02;
        cfg.reliable = true;
        artifacts(&cfg)
    };
    let failing = |threads: usize| {
        let mut cfg = obs_cfg(threads);
        cfg.encoders = 2;
        cfg.traffic.process = ArrivalProcess::Uniform { seqs_per_s: 2_000.0 };
        cfg.fail =
            Some(FailureSchedule { fpga: 2, at_cycle: 350_000, recovery_cycles: Some(100_000) });
        artifacts(&cfg)
    };
    for (name, run) in
        [("lossy", &lossy as &dyn Fn(usize) -> (ServingReport, String, String)), ("fail", &failing)]
    {
        let (r1, trace1, metrics1) = run(1);
        let (r8, trace8, metrics8) = run(8);
        assert_eq!(trace8, trace1, "{name}: trace diverged across threads");
        assert_eq!(metrics8, metrics1, "{name}: metrics diverged across threads");
        assert_eq!(
            r8.to_json().pretty(),
            r1.to_json().pretty(),
            "{name}: report diverged across threads"
        );
    }
}

/// Collection must not perturb the simulation: the v2 body of a
/// telemetry-on report equals the telemetry-off report byte for byte,
/// and the telemetry-off report is exactly the pre-telemetry schema.
#[test]
fn telemetry_off_reports_are_exactly_v2_and_collection_is_inert() {
    let mut cfg = obs_cfg(1);
    let (on, _, _) = artifacts(&cfg);
    cfg.obs.enabled = false;
    let off = run_serving(&cfg).unwrap();
    assert_eq!(off.schema(), "serving_report/v2");
    let off_json = off.to_json();
    assert!(off_json.get("telemetry").is_none() && off_json.get("sim_profile").is_none());
    validate_serving_report(&off_json).unwrap();

    // strip the v3 sections: everything else must match byte for byte
    let mut stripped = on.clone();
    stripped.telemetry = None;
    stripped.sim_profile = None;
    assert_eq!(
        stripped.to_json().pretty(),
        off_json.pretty(),
        "enabling telemetry perturbed the simulated results"
    );
}

/// §6 failover telemetry: failure/recovery instants land in the Chrome
/// trace, and the outage shows up in the bottleneck attribution.
#[test]
fn failover_telemetry_attributes_the_outage() {
    let mut cfg = obs_cfg(1);
    cfg.encoders = 2;
    cfg.traffic.process = ArrivalProcess::Uniform { seqs_per_s: 2_000.0 };
    cfg.fail = Some(FailureSchedule { fpga: 2, at_cycle: 350_000, recovery_cycles: Some(100_000) });
    let (r, trace, metrics) = artifacts(&cfg);
    let f = r.fault.clone().expect("failure injected");
    assert!(f.recovered);

    assert!(trace.contains("\"name\":\"fail\""), "failure instant missing from the trace");
    assert!(trace.contains("\"name\":\"recover\""), "recovery instant missing from the trace");
    let j = r.to_json();
    validate_serving_report(&j).unwrap();
    let outage =
        j.path("telemetry.attribution.totals_cycles.outage").unwrap().as_f64().unwrap();
    assert!(outage > 0.0, "mid-outage arrivals must carry outage cycles");
    assert_eq!(
        j.path("telemetry.fleet.outage_holds").unwrap().as_i64().unwrap(),
        f.held_packets as i64,
        "telemetry and fault section must agree on buffered packets"
    );
    // the outage also lands in the metrics summary line
    let summary = metrics.lines().last().unwrap();
    assert!(summary.contains("\"outage_holds\":"), "metrics summary missing outage holds");
    let sj = Json::parse(summary).unwrap();
    assert_eq!(sj.get("outage_holds").unwrap().as_i64().unwrap(), f.held_packets as i64);
}

/// Regression for the once-dead `KernelStats::wakes` counter: it is
/// collected, aggregated, exported per kernel, and consistent between
/// the metrics stream and the report's telemetry section.
#[test]
fn wakes_surface_in_metrics_and_telemetry() {
    let (r, _, metrics) = artifacts(&obs_cfg(1));
    let j = r.to_json();
    let total = j.path("telemetry.wakes.total").unwrap().as_i64().unwrap();
    assert!(total > 0, "a timing-mode serving run schedules wakes (PE pacing)");
    let top = j.path("telemetry.wakes.top_kernels").unwrap().as_arr().unwrap();
    assert!(!top.is_empty());
    assert!(top[0].get("wakes").unwrap().as_i64().unwrap() > 0);

    // per-kernel wakes in the metrics stream sum to the reported total
    let mut stream_total = 0i64;
    for line in metrics.lines() {
        let lj = Json::parse(line).unwrap();
        if lj.get("type").and_then(Json::as_str) == Some("kernel") {
            stream_total += lj.get("wakes").unwrap().as_i64().unwrap();
        }
    }
    assert_eq!(stream_total, total, "metrics stream and telemetry section disagree on wakes");
}

/// Every emitted artifact parses: the Chrome trace as one JSON document
/// with balanced async begin/end pairs, the metrics stream line by line
/// with a well-formed header.
#[test]
fn artifacts_are_well_formed() {
    let (r, trace, metrics) = artifacts(&obs_cfg(2));
    let doc = Json::parse(&trace).expect("trace must be valid JSON");
    let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    let (mut begins, mut ends) = (0, 0);
    for e in evs {
        match e.get("ph").and_then(Json::as_str).unwrap() {
            "b" => begins += 1,
            "e" => ends += 1,
            _ => {}
        }
    }
    assert_eq!(begins, ends, "unbalanced async span pairs");
    assert!(begins >= r.completed as i64, "at least one span per completed request");

    let header = Json::parse(metrics.lines().next().unwrap()).unwrap();
    assert_eq!(header.get("schema").unwrap().as_str().unwrap(), "obs_metrics/v1");
    assert!(header.get("interval_cycles").unwrap().as_i64().unwrap() > 0);
    for line in metrics.lines() {
        assert!(Json::parse(line).is_ok(), "unparseable metrics line: {line}");
    }
}
