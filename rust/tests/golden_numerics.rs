//! THE bit-exactness contract: rust integer compute vs the JAX reference,
//! via golden vectors exported by `make artifacts` — plus the decode
//! contract (incremental KV-cache generation vs full recompute), which
//! runs artifact-free on synthetic models.

use galapagos_llm::ibert::config::ModelConfig;
use galapagos_llm::ibert::encoder::{
    decode_generate, decode_generate_recompute, encoder_forward, model_forward, rows_i8, rows_i64,
};
use galapagos_llm::ibert::weights::{load_golden, synthetic_input, ModelParams};

fn artifacts() -> std::path::PathBuf {
    let d = ModelParams::default_dir();
    assert!(
        d.join("quantparams.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    d
}

#[test]
fn encoder_stages_match_goldens_m128() {
    let dir = artifacts();
    let p = ModelParams::load(&dir).unwrap();
    let x = rows_i8(load_golden(&dir, "input_m128").unwrap().as_i8().unwrap());
    let st = encoder_forward(&p, &x);

    let check_i8 = |name: &str, got: &[Vec<i8>]| {
        let want = rows_i8(load_golden(&dir, name).unwrap().as_i8().unwrap());
        assert_eq!(got.len(), want.len(), "{name}: row count");
        for (r, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "{name}: first mismatch at row {r}");
        }
    };
    check_i8("stage_q_m128", &st.q);
    check_i8("stage_k_m128", &st.k);
    check_i8("stage_v_m128", &st.v);
    check_i8("stage_att_m128", &st.att);
    check_i8("stage_ln1_m128", &st.ln1);
    check_i8("stage_gelu_in_m128", &st.gelu_in);
    check_i8("stage_mid_m128", &st.mid);
    check_i8("stage_out_m128", &st.out);

    // probs golden is [heads, m, m] int8
    let probs_t = load_golden(&dir, "stage_probs_m128").unwrap();
    let pt = probs_t.as_i8().unwrap();
    assert_eq!(pt.dims, vec![12, 128, 128]);
    for h in 0..12 {
        for r in 0..128 {
            for c in 0..128 {
                let want = pt.data[(h * 128 + r) * 128 + c];
                assert_eq!(
                    st.probs[h][r][c], want,
                    "probs mismatch at head {h} row {r} col {c}"
                );
            }
        }
    }

    // wide residual stages are int64
    let res = rows_i64(load_golden(&dir, "stage_res_m128").unwrap().as_i64().unwrap());
    assert_eq!(st.res, res, "res stage");
    let res2 = rows_i64(load_golden(&dir, "stage_res2_m128").unwrap().as_i64().unwrap());
    assert_eq!(st.res2, res2, "res2 stage");
}

#[test]
fn encoder_output_matches_goldens_all_lengths() {
    let dir = artifacts();
    let p = ModelParams::load(&dir).unwrap();
    let x128 = rows_i8(load_golden(&dir, "input_m128").unwrap().as_i8().unwrap());
    for m in [1usize, 8, 38, 64, 128] {
        let want = rows_i8(
            load_golden(&dir, &format!("encoder_out_m{m}")).unwrap().as_i8().unwrap(),
        );
        let got = encoder_forward(&p, &x128[..m]).out;
        assert_eq!(got, want, "encoder output mismatch at m={m}");
    }
}

/// Tiny deterministic LCG so the sweep below draws geometry, prompt and
/// generation lengths without any external RNG dependency.
struct Lcg(u64);
impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
    fn in_range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

#[test]
fn incremental_decode_is_bit_identical_to_full_recompute() {
    // artifact-free: random synthetic geometries, prompt lengths, and
    // generation lengths. The incremental KV-cache path must reproduce
    // the quadratic recompute oracle bit for bit — prefill matrix AND
    // every generated token row.
    let mut rng = Lcg(0xDEC0DE_8);
    for case in 0..8u64 {
        let heads = 12usize;
        let head_dim = [4usize, 8, 16][rng.in_range(0, 2) as usize];
        let hidden = heads * head_dim;
        let max_seq = 32usize;
        let cfg = ModelConfig { hidden, heads, ffn: 2 * hidden, max_seq, num_encoders: 2 };
        let p = ModelParams::synthetic(cfg, 0xABC0 + case);
        let layers = rng.in_range(1, 3) as usize;
        let max_new = rng.in_range(0, 6) as usize;
        let m = rng.in_range(1, (max_seq - max_new) as u64) as usize;
        let prompt = synthetic_input(hidden, m, 7 * case + 1);
        let (pre_i, toks_i) = decode_generate(&p, &prompt, layers, max_new);
        let (pre_r, toks_r) = decode_generate_recompute(&p, &prompt, layers, max_new);
        assert_eq!(
            pre_i, pre_r,
            "case {case}: prefill mismatch (h={hidden} L={layers} m={m} n={max_new})"
        );
        assert_eq!(
            toks_i, toks_r,
            "case {case}: token mismatch (h={hidden} L={layers} m={m} n={max_new})"
        );
        assert_eq!(toks_i.len(), max_new);
    }
}

#[test]
fn batched_decode_passes_match_independent_generation() {
    // continuous batching groups token rows from different in-flight
    // sequences into one weight-stationary pass; the grouping must
    // change WHEN passes run, never WHAT they compute. Drive the
    // functional simulator with three concurrent requests of different
    // prompt lengths under a batch cap of 3 and byte-diff every
    // request's prefill matrix and generated token rows against the
    // native incremental decoder run for that sequence alone.
    use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
    use galapagos_llm::ibert::kernels::Mode;
    use galapagos_llm::ibert::timing::PeConfig;
    use galapagos_llm::serve::{BatchConfig, DecodeConfig, Request};
    use std::sync::Arc;

    let cfg_m = ModelConfig { hidden: 96, heads: 12, ffn: 192, max_seq: 32, num_encoders: 2 };
    let p = Arc::new(ModelParams::synthetic(cfg_m, 0xBA7C4));
    let max_new = 4usize;
    let prompt_ms = [2usize, 5, 8];
    let input = Arc::new(synthetic_input(cfg_m.hidden, *prompt_ms.iter().max().unwrap(), 51));
    let block = 1 + max_new as u32;
    let tb_cfg = TestbedConfig {
        encoders: 2,
        m: 8,
        inferences: prompt_ms.len() as u32,
        interval: 12,
        pe: PeConfig::default(),
        mode: Mode::Functional(p.clone()),
        fpgas_per_switch: 6,
        input: Some(input.clone()),
        placement: None,
        schedule: Some(Arc::new(
            prompt_ms
                .iter()
                .enumerate()
                .map(|(i, &m)| Request { arrival: i as u64 * 50, m: m as u32 })
                .collect(),
        )),
        decode: Some(DecodeConfig { max_new_tokens: max_new as u32 }),
        batching: Some(BatchConfig { max: prompt_ms.len() as u32, window: 20_000 }),
        threads: Some(1),
        granularity: None,
        net: Default::default(),
        fail: None,
        obs: Default::default(),
    };
    let mut tb = build_testbed(&tb_cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    let sink = tb.sink.lock().unwrap();
    for (r, &m) in prompt_ms.iter().enumerate() {
        let (pre, toks) = decode_generate(&p, &input[..m], 2, max_new);
        let base = r as u32 * block;
        assert_eq!(sink.matrix(base).unwrap(), pre, "request {r} (m={m}) prefill mismatch");
        for (s, tok) in toks.iter().enumerate() {
            let got = sink.matrix(base + 1 + s as u32).unwrap();
            assert_eq!(got.len(), 1, "token pass must be a single row");
            assert_eq!(&got[0], tok, "request {r} (m={m}) token {} mismatch", s + 1);
        }
    }
    // the assertion above is only interesting if rows actually shared
    // a pass: the assembler must have released at least one real batch
    let log = tb.batch_log.as_ref().unwrap().lock().unwrap();
    assert!(log.releases.iter().any(|&(_, sz)| sz >= 2), "no batch formed: {:?}", log.releases);
}

#[test]
fn model12_matches_golden() {
    let dir = artifacts();
    let p = ModelParams::load(&dir).unwrap();
    let x128 = rows_i8(load_golden(&dir, "input_m128").unwrap().as_i8().unwrap());
    let want = rows_i8(load_golden(&dir, "model12_out_m38").unwrap().as_i8().unwrap());
    let got = model_forward(&p, &x128[..38], 12);
    assert_eq!(got, want, "12-encoder model output mismatch");
}
