//! Failure injection: the paper's §2.1/§6 operational claims.
//!
//! * UDP is unreliable ("it works well-enough in our testbed"): the lossy
//!   network mode must degrade gracefully — packets vanish, the platform
//!   does not wedge or corrupt.
//! * Cluster-level fault isolation (§6): "When one FPGA fails in a
//!   cluster, only the cluster that holds the failed FPGA needs to be
//!   re-configured ... packets that are sent to this cluster will be
//!   buffered in the cluster input buffer."

use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::sim::fifo::Fifo;

#[test]
fn lossy_network_loses_work_but_never_wedges() {
    let mut cfg = TestbedConfig::proof_of_concept(16, Mode::Timing);
    cfg.inferences = 2;
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.fabric.drop_probability = 0.02; // 2% UDP loss
    tb.sim.start();
    tb.sim.run().unwrap(); // must terminate (no deadlock on missing rows)
    assert!(tb.sim.fabric.stats.dropped > 0, "losses should have occurred");
    // dropped rows stall the matrix-buffering kernels (attention waits
    // for a K matrix that never completes) — deliveries shrink or vanish,
    // but the event queue always drains and nothing is duplicated
    let sink = tb.sink.lock().unwrap();
    let delivered: u32 = sink.arrivals.values().map(|&(n, _)| n).sum();
    assert!(
        delivered <= 2 * 16,
        "delivered more rows than were sent ({delivered})"
    );
}

#[test]
fn reliable_network_delivers_everything() {
    // control for the test above: zero loss => exact delivery
    let mut cfg = TestbedConfig::proof_of_concept(16, Mode::Timing);
    cfg.inferences = 2;
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    assert_eq!(tb.sim.fabric.stats.dropped, 0);
    let sink = tb.sink.lock().unwrap();
    let delivered: u32 = sink.arrivals.values().map(|&(n, _)| n).sum();
    assert_eq!(delivered, 2 * 16);
}

#[test]
fn cluster_input_buffer_absorbs_a_stalled_cluster() {
    // §6's fault-isolation mechanism in miniature: traffic to a cluster
    // lands at its gateway; if the cluster stalls (reconfiguration), the
    // gateway FIFO buffers the in-flight matrix — the paper's "one input
    // buffer per cluster" sizing rule.
    let fifo = Fifo::for_matrix(128, 768);
    let mut f = fifo.clone();
    // a full matrix arrives while the cluster is being reconfigured
    for _ in 0..128 {
        f.push(768);
    }
    assert_eq!(f.overflows, 0, "one-matrix buffer absorbs the burst");
    assert_eq!(f.high_water, 128 * 768);
    // anything beyond one matrix overflows — the rule is tight
    f.push(768);
    assert_eq!(f.overflows, 1);
}

#[test]
fn fifo_highwater_is_tracked_in_running_sim() {
    // the LN1 kernel's FIFO really does hold the residual matrix while
    // attention drains (the behavior that motivates the paper's sizing)
    let cfg = TestbedConfig::proof_of_concept(128, Mode::Timing);
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    let ln1 = galapagos_llm::sim::packet::GlobalKernelId::new(0, 29);
    let fifo = tb.sim.fifo_of(ln1).unwrap();
    assert!(
        fifo.high_water >= 128 * 768,
        "LN1 FIFO must have buffered the full residual matrix (high water {})",
        fifo.high_water
    );
    assert_eq!(fifo.overflows, 0, "the cluster builder sized it correctly");
}
