//! Failure injection: the paper's §2.1/§6 operational claims.
//!
//! * UDP is unreliable ("it works well-enough in our testbed"): the lossy
//!   network mode must degrade gracefully — packets vanish, the platform
//!   does not wedge or corrupt — and with the reliable ack/retransmit
//!   layer on, lossy runs complete every inference exactly once.
//! * Cluster-level fault isolation (§6): "When one FPGA fails in a
//!   cluster, only the cluster that holds the failed FPGA needs to be
//!   re-configured ... packets that are sent to this cluster will be
//!   buffered in the cluster input buffer" — plus the recovery half:
//!   incremental re-placement, reconfiguration latency, in-order drain.

use galapagos_llm::eval::testbed::{build_testbed, FailureSchedule, TestbedConfig};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::serve::{
    run_serving, validate_serving_report, ArrivalProcess, DecodeConfig, ServeConfig,
};
use galapagos_llm::sim::fifo::Fifo;

#[test]
fn lossy_network_loses_work_but_never_wedges() {
    let mut cfg = TestbedConfig::proof_of_concept(16, Mode::Timing);
    cfg.inferences = 2;
    cfg.net.drop_probability = 0.02; // 2% UDP loss
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap(); // must terminate (no deadlock on missing rows)
    assert!(tb.sim.fabric.stats.dropped > 0, "losses should have occurred");
    // dropped rows stall the matrix-buffering kernels (attention waits
    // for a K matrix that never completes) — deliveries shrink or vanish,
    // but the event queue always drains and nothing is duplicated
    let sink = tb.sink.lock().unwrap();
    let delivered: u32 = sink.arrivals.values().map(|&(n, _)| n).sum();
    assert!(
        delivered <= 2 * 16,
        "delivered more rows than were sent ({delivered})"
    );
    // the stats contract holds: drops are counted apart from deliveries
    let s = &tb.sim.fabric.stats;
    assert_eq!(s.packets, s.intra_fpga_packets + s.inter_fpga_packets + s.dropped);
    assert_eq!(s.retransmits, 0, "no retransmissions without reliable transport");
}

#[test]
fn reliable_transport_completes_every_inference_under_loss() {
    // the tentpole acceptance scenario: 2% UDP loss + ack/retransmit =>
    // every inference completes, delivered exactly once
    let mut cfg = TestbedConfig::proof_of_concept(16, Mode::Timing);
    cfg.inferences = 2;
    cfg.net.drop_probability = 0.02;
    cfg.net.reliable = true;
    cfg.net.seed = 7;
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    let s = &tb.sim.fabric.stats;
    assert!(s.dropped > 0, "losses should have occurred at 2%");
    assert_eq!(s.dropped, s.retransmits, "every lost copy was retransmitted");
    assert_eq!(s.packets, s.intra_fpga_packets + s.inter_fpga_packets, "no packet lost");
    // exactly-once, verified against the sink: the full output of both
    // inferences arrived, no row duplicated
    let sink = tb.sink.lock().unwrap();
    let delivered: u32 = sink.arrivals.values().map(|&(n, _)| n).sum();
    assert_eq!(delivered, 2 * 16, "reliable lossy run must deliver everything");
    // ... and against the per-link sequence numbers
    for ((src, dst), seq) in tb.sim.fabric.link_audit() {
        assert_eq!(
            seq.sent, seq.delivered,
            "link {src:?}->{dst:?} violated exactly-once: {seq:?}"
        );
    }
}

#[test]
fn lossy_runs_are_seed_deterministic_and_seeds_differ() {
    // regression for the hard-seeded drop RNG: the pattern must derive
    // from the run seed, not a constant
    let run = |seed: u64| {
        let mut cfg = TestbedConfig::proof_of_concept(16, Mode::Timing);
        cfg.inferences = 2;
        cfg.net.drop_probability = 0.02;
        cfg.net.seed = seed;
        let mut tb = build_testbed(&cfg).unwrap();
        tb.sim.start();
        tb.sim.run().unwrap();
        tb.sim.fabric.drop_trace.clone()
    };
    let a = run(1);
    assert_eq!(a, run(1), "same seed must reproduce the exact drop trace");
    assert_ne!(a, run(2), "different seeds must produce different drop patterns");
    assert!(!a.is_empty(), "the 2% run must actually drop something");
}

#[test]
fn lossy_runs_are_thread_count_invariant() {
    // lossy runs execute on the sharded engine at --threads > 1: the
    // per-link drop RNG streams make every link's drop sequence a
    // function of its own traffic, so results are bit-identical at
    // --threads 1 vs --threads 8
    let run = |threads: usize, reliable: bool| {
        let mut cfg = TestbedConfig::proof_of_concept(16, Mode::Timing);
        cfg.encoders = 2; // multi-shard-shaped fleet: real cross-shard links
        cfg.inferences = 2;
        cfg.threads = Some(threads);
        cfg.net.drop_probability = 0.02;
        cfg.net.reliable = reliable;
        cfg.net.seed = 11;
        let mut tb = build_testbed(&cfg).unwrap();
        tb.sim.start();
        tb.sim.run().unwrap();
        let sink = tb.sink.lock().unwrap();
        let delivered: u32 = sink.arrivals.values().map(|&(n, _)| n).sum();
        (
            tb.sim.time,
            tb.sim.trace.events_processed,
            tb.sim.fabric.stats.packets,
            tb.sim.fabric.stats.dropped,
            tb.sim.fabric.drop_trace.clone(),
            delivered,
        )
    };
    for reliable in [false, true] {
        let seq = run(1, reliable);
        assert_eq!(run(8, reliable), seq, "lossy run diverged at 8 threads");
    }
}

#[test]
fn reliable_network_delivers_everything() {
    // control for the test above: zero loss => exact delivery
    let mut cfg = TestbedConfig::proof_of_concept(16, Mode::Timing);
    cfg.inferences = 2;
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    assert_eq!(tb.sim.fabric.stats.dropped, 0);
    let sink = tb.sink.lock().unwrap();
    let delivered: u32 = sink.arrivals.values().map(|&(n, _)| n).sum();
    assert_eq!(delivered, 2 * 16);
}

/// Mid-serving failover, end to end: uniform arrivals, one FPGA of
/// encoder 0 dies between two arrivals, the cluster input buffer absorbs
/// the traffic of the outage, recovery re-places the displaced kernels,
/// and the backlog drains — all deterministic across thread counts.
fn failover_cfg(threads: usize) -> ServeConfig {
    let mut cfg = ServeConfig::glue(2, 12, 2_000.0, 3);
    // exact arrivals every 100k cycles: requests 0..3 land before the
    // failure, request 4 (at 400k) arrives mid-outage, 5.. after recovery
    cfg.traffic.process = ArrivalProcess::Uniform { seqs_per_s: 2_000.0 };
    cfg.fail = Some(FailureSchedule {
        fpga: 2,
        at_cycle: 350_000,
        recovery_cycles: Some(100_000),
    });
    cfg.threads = Some(threads);
    cfg
}

#[test]
fn mid_serving_failover_recovers_and_reports() {
    let r = run_serving(&failover_cfg(1)).unwrap();
    let f = r.fault.clone().expect("failure was injected: fault section required");
    assert!(f.recovered, "the outage lies mid-run: recovery must have executed");
    assert_eq!((f.fpga, f.cluster), (2, 0));
    assert_eq!(f.fail_cycle, 350_000);
    assert_eq!(f.recover_cycle, 450_000);
    assert_eq!(f.time_to_recover_cycles(), 100_000);
    assert!(f.moved_kernels > 0, "the failed FPGA's kernels must be re-placed");
    assert!(f.input_buffer_bytes > 0, "the §6 cluster input buffer has a real capacity");
    assert!(f.input_buffer_peak > 0.0, "the outage backlog must have occupied it");
    assert!(
        f.held_packets > 0,
        "request 4 arrives mid-outage: its rows must buffer at the cluster input"
    );
    // every request is accounted for: completed, or lost to the fault
    assert_eq!(r.completed + f.incomplete_requests, r.requests);
    assert!(
        r.completed >= r.requests - 2,
        "only requests straddling the failure may be lost ({}/{})",
        r.completed,
        r.requests
    );
    // the mid-outage arrival completed after the drain, so the fault
    // section carries outage-window percentiles, and its latency is at
    // least the time it sat in the cluster input buffer (~50k cycles)
    let w = f.recovery_window.expect("a request arrived during the outage");
    assert!(w.max >= 50_000, "outage-window latency must include the buffering wait");
    assert!(r.latency.p99 >= r.latency.p50);
}

#[test]
fn unreached_failure_window_is_reported_honestly() {
    // the failure is scheduled far beyond the run's last event: no
    // outage occurs, and the fault section must say so instead of
    // presenting a fictitious recovery
    let mut cfg = failover_cfg(1);
    cfg.fail = Some(FailureSchedule {
        fpga: 2,
        at_cycle: u64::MAX / 2,
        recovery_cycles: Some(100_000),
    });
    let r = run_serving(&cfg).unwrap();
    let f = r.fault.clone().expect("fault section still present");
    assert!(!f.recovered, "the run never reached the failure window");
    assert_eq!((f.held_packets, f.lost_events), (0, 0));
    assert_eq!(r.completed, r.requests, "nothing was lost to a failure that never happened");
    assert!(r.render().contains("no outage occurred"));
}

#[test]
fn failover_reports_are_deterministic_across_threads_and_runs() {
    let golden = run_serving(&failover_cfg(1)).unwrap().to_json().pretty();
    assert_eq!(
        run_serving(&failover_cfg(1)).unwrap().to_json().pretty(),
        golden,
        "same seed, same failover report"
    );
    assert_eq!(
        run_serving(&failover_cfg(8)).unwrap().to_json().pretty(),
        golden,
        "failure injection must be thread-count-invariant (phased sharded engine)"
    );
}

/// Mid-decode failover: the FPGA dies while feedback passes are in
/// flight, so the outage can cut a request between its prefill and one
/// of its token passes. The fault section must own exactly what the
/// failure cost, the report must still validate as v4, and the whole
/// thing must stay bit-identical across thread counts.
#[test]
fn mid_decode_failover_recovers_and_stays_thread_invariant() {
    let decode_cfg = |threads: usize| {
        let mut cfg = failover_cfg(threads);
        cfg.decode = Some(DecodeConfig { max_new_tokens: 2 });
        cfg
    };
    let r = run_serving(&decode_cfg(1)).unwrap();
    assert_eq!(r.schema(), "serving_report/v4");
    validate_serving_report(&r.to_json()).unwrap();
    let f = r.fault.clone().expect("failure was injected: fault section required");
    assert!(f.recovered, "the outage lies mid-run: recovery must have executed");
    assert!(f.moved_kernels > 0, "the failed FPGA's kernels must be re-placed");
    // every request is accounted for: completed (prefill + ALL token
    // passes), or charged to the fault
    assert_eq!(r.completed + f.incomplete_requests, r.requests);
    assert!(
        r.completed >= r.requests - 3,
        "only requests straddling the outage may lose passes ({}/{})",
        r.completed,
        r.requests
    );
    // completed requests generate exactly max_new_tokens each; a request
    // cut mid-decode may still have landed its first token pass
    let d = r.decode.as_ref().expect("v4 report carries the decode section");
    assert_eq!(d.max_new_tokens, 2);
    let gen = d.generated_tokens as usize;
    assert!(
        gen >= 2 * r.completed && gen <= 2 * r.completed + f.incomplete_requests,
        "generated_tokens {gen} inconsistent with {} completed / {} incomplete",
        r.completed,
        f.incomplete_requests
    );
    // bit-identical at 8 threads, fault section and decode metrics included
    let golden = r.to_json().pretty();
    assert_eq!(
        run_serving(&decode_cfg(8)).unwrap().to_json().pretty(),
        golden,
        "mid-decode failover must be thread-count-invariant"
    );
}

#[test]
fn lossy_reliable_failover_still_completes_the_survivors() {
    // loss AND failure at once: the transport retries what the network
    // eats, the fault section owns what the failure cost
    let mut cfg = failover_cfg(1);
    cfg.drop_probability = 0.01;
    cfg.reliable = true;
    let r = run_serving(&cfg).unwrap();
    assert_eq!(r.dropped, r.retransmits);
    let f = r.fault.expect("fault section present");
    assert_eq!(r.completed + f.incomplete_requests, r.requests);
    assert!(r.completed >= r.requests - 2);
}

#[test]
fn cluster_input_buffer_absorbs_a_stalled_cluster() {
    // §6's fault-isolation mechanism in miniature: traffic to a cluster
    // lands at its gateway; if the cluster stalls (reconfiguration), the
    // gateway FIFO buffers the in-flight matrix — the paper's "one input
    // buffer per cluster" sizing rule.
    let fifo = Fifo::for_matrix(128, 768);
    let mut f = fifo.clone();
    // a full matrix arrives while the cluster is being reconfigured
    for _ in 0..128 {
        f.push(768);
    }
    assert_eq!(f.overflows, 0, "one-matrix buffer absorbs the burst");
    assert_eq!(f.high_water, 128 * 768);
    // anything beyond one matrix overflows — the rule is tight
    f.push(768);
    assert_eq!(f.overflows, 1);
}

#[test]
fn fifo_highwater_is_tracked_in_running_sim() {
    // the LN1 kernel's FIFO really does hold the residual matrix while
    // attention drains (the behavior that motivates the paper's sizing)
    let cfg = TestbedConfig::proof_of_concept(128, Mode::Timing);
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    let ln1 = galapagos_llm::sim::packet::GlobalKernelId::new(0, 29);
    let fifo = tb.sim.fifo_of(ln1).unwrap();
    assert!(
        fifo.high_water >= 128 * 768,
        "LN1 FIFO must have buffered the full residual matrix (high water {})",
        fifo.high_water
    );
    assert_eq!(fifo.overflows, 0, "the cluster builder sized it correctly");
}
