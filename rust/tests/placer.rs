//! Placer scenario tests: the acceptance criteria of the automatic
//! partitioner/placer subsystem.
//!
//! * the paper config reproduces the Fig. 14 six-FPGA mapping and the
//!   cost model tracks the discrete-event simulator within 10%;
//! * non-paper scenarios (BERT-large shape, heterogeneous fleet,
//!   SQuAD-length builds) produce valid resource-fit-checked plans;
//! * plans flow through the Cluster Builder and description files.

use galapagos_llm::cluster_builder::description::BuildDescription;
use galapagos_llm::eval::workload::GlueWorkload;
use galapagos_llm::fpga::resources::Device;
use galapagos_llm::gmi::Out;
use galapagos_llm::ibert::graph::{self, EncoderGraphParams};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::timing::PeConfig;
use galapagos_llm::placer::{
    cost, place, report, validate, Fleet, KernelGraph, ModelShape, Placement, Plan, SearchParams,
};
use galapagos_llm::sim::packet::GlobalKernelId;

fn paper_solution() -> galapagos_llm::placer::PlacementSolution {
    let fleet = Fleet::paper();
    place(&ModelShape::ibert_base(), &PeConfig::default(), &fleet, &SearchParams::default())
        .unwrap()
}

#[test]
fn paper_config_reproduces_fig14_six_fpga_mapping() {
    let sol = paper_solution();
    assert_eq!(sol.slots_used, 6);
    let want: Vec<usize> = (0..graph::KERNELS_PER_ENCODER as u8).map(graph::fpga_slot).collect();
    assert_eq!(sol.placement.slot_of, want, "auto placement must match the paper's manual mapping");
}

#[test]
fn cost_model_tracks_simulator_within_10_percent() {
    // the headline acceptance check: predicted end-to-end latency of the
    // placed paper config vs the discrete-event simulator replaying the
    // exact same placement
    let sol = paper_solution();
    let fleet = Fleet::paper();
    for m in [64usize, 128] {
        let pred = cost::estimate(&sol.graph, &sol.placement, &fleet, m, 12).unwrap();
        let (x, t, _i) =
            validate::replay_in_simulator(&sol.graph, &sol.placement, &fleet, m).unwrap();
        let t_err = (pred.t as f64 - t as f64).abs() / t as f64;
        assert!(
            t_err < 0.10,
            "m={m}: predicted T {} vs simulated {t} ({:.1}% off)",
            pred.t,
            100.0 * t_err
        );
        let x_err = (pred.x as f64 - x as f64).abs() / x as f64;
        assert!(
            x_err < 0.20,
            "m={m}: predicted X {} vs simulated {x} ({:.1}% off)",
            pred.x,
            100.0 * x_err
        );
    }
}

#[test]
fn placed_plan_flows_into_cluster_builder() {
    // placement -> ClusterSpec -> validated platform + Tcl/manifest
    let sol = paper_solution();
    let gp = EncoderGraphParams {
        cluster_id: 0,
        fpga_base: 0,
        pe: PeConfig::default(),
        mode: Mode::Timing,
        out_dst: Out::to(GlobalKernelId::new(200, 2)),
        max_seq: 128,
        hidden: 768,
        ffn: 3072,
        decode: None,
        batched: false,
    };
    let built = validate::to_encoder_build(&sol.graph, &sol.placement, &gp).unwrap();
    built.cluster.validate().unwrap();
    assert_eq!(built.cluster.fpgas().len(), 6);
    let dir = std::env::temp_dir().join(format!("placer_cb_{}", std::process::id()));
    let n = galapagos_llm::cluster_builder::ip_generator::generate(
        &built.cluster,
        &PeConfig::default(),
        Device::Xczu19eg,
        128,
        768,
        3072,
        &dir,
    )
    .unwrap();
    assert_eq!(n, 38);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bert_large_shape_gets_a_valid_plan() {
    // scenario 1 of the acceptance criteria: hidden=1024, ffn=4096,
    // 16 heads on a 12-FPGA XCZU19EG fleet
    let fleet = Fleet::homogeneous(Device::Xczu19eg, 12, 6);
    let sp = SearchParams::default();
    let sol = place(&ModelShape::bert_large(), &PeConfig::default(), &fleet, &sp).unwrap();
    let reports = validate::check(&sol.graph, &sol.placement, &fleet).unwrap();
    assert!(reports.iter().all(|r| r.fits()), "every FPGA within its full budget");
    assert!(sol.graph.shape.ffn_split >= 2, "4 MB FFN weights force a split");
    assert!(sol.slots_used > 6 && sol.slots_used <= 12, "used {} slots", sol.slots_used);
    // every kernel assigned exactly once
    assert_eq!(sol.placement.slot_of.len(), sol.graph.n_kernels());
}

#[test]
fn heterogeneous_fleet_gets_a_valid_plan() {
    // scenario 2: two VCK190s in front of four Sidewinders
    let d = BuildDescription::load(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/hetero_fleet.json"),
    )
    .unwrap();
    let fleet = d.fleet();
    assert_eq!(fleet.device(0), Device::Xcvc1902);
    assert_eq!(fleet.device(5), Device::Xczu19eg);
    let sol = place(&d.shape(), &d.pe, &fleet, &SearchParams::default()).unwrap();
    let reports = validate::check(&sol.graph, &sol.placement, &fleet).unwrap();
    assert!(reports.iter().all(|r| r.fits()));
    assert_eq!(sol.placement.slot_of.len(), 38);
    // the placement report renders with both device names
    let table = report::placement_table(&sol.graph, &sol.placement, &fleet).render();
    assert!(table.contains("xcvc1902") && table.contains("xczu19eg"));
}

#[test]
fn squad_length_build_places_and_scales_with_workload() {
    // satellite scenario: a long-sequence (SQuAD-like) build point —
    // max_seq 384 blows up the attention FIFOs, needing a larger fleet
    let shape = ModelShape { max_seq: 384, ..ModelShape::ibert_base() };
    let fleet = Fleet::homogeneous(Device::Xczu19eg, 12, 6);
    let sol = place(&shape, &PeConfig::default(), &fleet, &SearchParams::for_m(384)).unwrap();
    validate::check(&sol.graph, &sol.placement, &fleet).unwrap();
    assert!(sol.slots_used >= 6, "long-seq build should not shrink below the paper's six");

    // drive the cost model with SQuAD-sampled sequence lengths: latency
    // must track the workload's length spread (no-padding property)
    let mut wl = GlueWorkload::squad(42);
    let lens = wl.sample_n(64);
    let (mut lo, mut hi) = (u64::MAX, 0u64);
    for &m in &lens {
        let e = cost::estimate(&sol.graph, &sol.placement, &fleet, m.min(384), 12).unwrap();
        lo = lo.min(e.t);
        hi = hi.max(e.t);
    }
    assert!(hi > lo * 2, "SQuAD length spread must show up in latency: {lo}..{hi}");
}

#[test]
fn plan_roundtrips_through_description_and_json() {
    let d = BuildDescription::load(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/ibert_poc.json"),
    )
    .unwrap();
    let sol = place(&d.shape(), &d.pe, &d.fleet(), &SearchParams::for_m(d.max_seq)).unwrap();
    let plan = Plan {
        shape: sol.graph.shape,
        fleet: d.fleet(),
        placement: sol.placement.clone(),
        predicted: sol.predicted,
    };
    let back = Plan::parse(&plan.to_json().pretty()).unwrap();
    assert_eq!(back, plan);
    // and the description itself round-trips
    let d2 = BuildDescription::parse(&d.to_json().pretty()).unwrap();
    assert_eq!(d2, d);
}

#[test]
fn replayed_custom_placement_changes_simulated_timing() {
    // a deliberately bad placement (pipeline spread over two switches)
    // must simulate slower than Fig. 14 — end-to-end proof that the
    // placement vector actually drives the simulator
    let g = KernelGraph::encoder(ModelShape::ibert_base(), PeConfig::default()).unwrap();
    let fleet = Fleet::homogeneous(Device::Xczu19eg, 12, 6);
    let (_, t_good, _) =
        validate::replay_in_simulator(&g, &Placement::fig14(), &fleet, 64).unwrap();
    // same stage structure, but stages pushed onto slots 6..11 (switch 1)
    // every other stage: each stage boundary now crosses a switch
    let spread = Placement {
        slot_of: Placement::fig14()
            .slot_of
            .iter()
            .map(|&s| if s % 2 == 1 { s + 6 } else { s })
            .collect(),
    };
    let (_, t_spread, _) = validate::replay_in_simulator(&g, &spread, &fleet, 64).unwrap();
    assert!(
        t_spread > t_good,
        "cross-switch placement must be slower: {t_spread} <= {t_good}"
    );
}

#[test]
fn fleet_too_small_is_a_clean_error() {
    let fleet = Fleet::homogeneous(Device::Xczu19eg, 2, 6);
    let sp = SearchParams::default();
    let err = place(&ModelShape::ibert_base(), &PeConfig::default(), &fleet, &sp).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fleet") || msg.contains("fit"), "unhelpful error: {msg}");
}
