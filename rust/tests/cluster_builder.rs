//! Cluster Builder integration: description file -> platform -> running
//! simulation, plus IP generation outputs.

use galapagos_llm::cluster_builder::description::BuildDescription;
use galapagos_llm::cluster_builder::ip_generator;
use galapagos_llm::cluster_builder::layer_builder::validate_fit;
use galapagos_llm::eval::testbed::build_testbed;
use galapagos_llm::fpga::resources::Device;
use galapagos_llm::gmi::Out;
use galapagos_llm::ibert::graph::{build_encoder, EncoderGraphParams};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::timing::PeConfig;
use galapagos_llm::sim::packet::GlobalKernelId;

#[test]
fn description_to_running_simulation() {
    let d = BuildDescription::parse(
        r#"{"model": "ibert-base", "encoders": 2, "fpgas_per_switch": 6}"#,
    )
    .unwrap();
    let cfg = d.testbed(16, 1, 12, Mode::Timing);
    let mut tb = build_testbed(&cfg).unwrap();
    tb.sim.start();
    tb.sim.run().unwrap();
    let (x, t, _) = tb.sim.trace.xti(tb.sink_id).unwrap();
    assert!(t > x && x > 0);
    // two encoders: 12 FPGAs + eval, split over 3 switches
    assert_eq!(tb.spec.switch_of.len(), 13);
}

#[test]
fn config_files_parse() {
    for f in ["configs/ibert_poc.json", "configs/ibert_full.json"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(f);
        let d = BuildDescription::load(&path).unwrap_or_else(|e| panic!("{f}: {e:#}"));
        assert_eq!(d.model, "ibert-base");
    }
}

#[test]
fn custom_pe_config_affects_timing() {
    // halve the linear MAC array => the encoder slows ~2x (the paper's
    // resource/latency trade the Layer Description File exposes)
    let d_fast = BuildDescription::parse(r#"{"pe": {"linear_macs": 768}}"#).unwrap();
    let d_slow = BuildDescription::parse(r#"{"pe": {"linear_macs": 384, "ffn_macs": 1536}}"#).unwrap();
    let run = |d: &BuildDescription| {
        let mut tb = build_testbed(&d.testbed(64, 1, 12, Mode::Timing)).unwrap();
        tb.sim.start();
        tb.sim.run().unwrap();
        tb.sim.trace.xti(tb.sink_id).unwrap().1
    };
    let t_fast = run(&d_fast);
    let t_slow = run(&d_slow);
    let ratio = t_slow as f64 / t_fast as f64;
    assert!(ratio > 1.7 && ratio < 2.3, "halving MACs should ~double latency, got {ratio:.2}");
}

#[test]
fn ip_generator_emits_full_build() {
    let cluster = build_encoder(&EncoderGraphParams {
        cluster_id: 0,
        fpga_base: 0,
        pe: PeConfig::default(),
        mode: Mode::Timing,
        out_dst: Out::to(GlobalKernelId::new(200, 2)),
        max_seq: 128,
        hidden: 768,
        ffn: 3072,
        decode: None,
        batched: false,
    })
    .cluster;
    let dir = std::env::temp_dir().join(format!("cb_int_{}", std::process::id()));
    let n = ip_generator::generate(&cluster, &PeConfig::default(), Device::Xczu19eg, 128, 768,
                                   3072, &dir)
        .unwrap();
    assert_eq!(n, 38);
    assert!(dir.join("cluster_build.json").exists());
    // every kernel has a Tcl script
    for id in 0..38 {
        assert!(dir.join(format!("kern_{id}.tcl")).exists(), "kern_{id}.tcl missing");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn platform_fits_device_budgets() {
    let d = BuildDescription::parse(r#"{"encoders": 12}"#).unwrap();
    let cfg = d.testbed(128, 1, 12, Mode::Timing);
    let tb = build_testbed(&cfg).unwrap();
    // skip the eval cluster (not an encoder build)
    let spec = galapagos_llm::galapagos::cluster::PlatformSpec {
        clusters: tb.spec.clusters.iter().filter(|c| c.id != 200).cloned().collect(),
        switch_of: tb.spec.switch_of.clone(),
    };
    validate_fit(&spec, &d.pe, d.device, d.max_seq, 768, 3072).unwrap();
}

#[test]
fn routing_tables_built_for_all_fpgas() {
    let d = BuildDescription::parse(r#"{"encoders": 3}"#).unwrap();
    let tb = build_testbed(&d.testbed(8, 1, 12, Mode::Timing)).unwrap();
    let tables = tb.spec.routing_tables().unwrap();
    // 18 encoder FPGAs + 1 eval FPGA
    assert_eq!(tables.len(), 19);
    for rt in tables.values() {
        // every FPGA knows the gateways of the other clusters (2N-1 rule)
        assert!(rt.entries() >= 3);
    }
}
