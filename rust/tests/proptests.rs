//! Property-based tests on coordinator invariants: routing, GMI
//! collectives, batching/pipelining, and the integer-op contracts.
//! Uses the in-crate quickcheck mini-framework (seeded, replayable).

use galapagos_llm::fpga::resources::Device;
use galapagos_llm::galapagos::cluster::{ClusterSpec, KernelDecl, KernelType, PlatformSpec};
use galapagos_llm::gmi::{GmiKernel, GmiOp, Out, ReduceFn, ScatterPolicy};
use galapagos_llm::ibert::compute;
use galapagos_llm::ibert::config::RequantSite;
use galapagos_llm::ibert::timing::PeConfig;
use galapagos_llm::placer::{self, Fleet, ModelShape, Plan, SearchParams};
use galapagos_llm::prop_assert;
use galapagos_llm::sim::engine::{KernelBehavior, KernelIo, START_TAG};
use galapagos_llm::sim::fabric::{FpgaId, SwitchId};
use galapagos_llm::sim::fifo::Fifo;
use galapagos_llm::sim::packet::{GlobalKernelId, MsgMeta, Packet, Payload};
use galapagos_llm::sim::Sim;
use galapagos_llm::util::quickcheck::{check, check_with, Config};

fn k(c: u8, n: u8) -> GlobalKernelId {
    GlobalKernelId::new(c, n)
}

// ---------------------------------------------------------------------------
// GMI collectives: scatter/gather roundtrips over random row sets
// ---------------------------------------------------------------------------

struct Tx {
    dst: GlobalKernelId,
    rows: Vec<Vec<i32>>,
    stream: u8,
}
impl KernelBehavior for Tx {
    fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            let n = self.rows.len() as u32;
            for (i, r) in self.rows.iter().enumerate() {
                io.send(
                    self.dst,
                    MsgMeta { stream: self.stream, row: i as u32, rows: n, inference: 0 },
                    Payload::row_i32(r.clone()),
                );
            }
        }
    }
}

struct Collect {
    got: std::sync::Arc<std::sync::Mutex<Vec<(u32, Vec<i32>)>>>,
}
impl KernelBehavior for Collect {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        let got = self.got.clone();
        io.rows(pkt, |io2: &mut KernelIo, meta, _at, payload| {
            io2.consume(payload.bytes());
            if let Payload::RowI32(v) = payload {
                got.lock().unwrap().push((meta.row, (*v).clone()));
            }
        });
    }
    fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
}

#[test]
fn prop_scatter_gather_roundtrip_preserves_rows() {
    check_with(&Config { cases: 48, ..Default::default() }, "scatter-gather-roundtrip", |g| {
        let n_rows = g.usize_in(1, 24);
        let n_lanes = g.usize_in(1, 4);
        let rows: Vec<Vec<i32>> =
            (0..n_rows).map(|_| (0..3).map(|_| g.i64_in(-1000, 1000) as i32).collect()).collect();

        let mut sim = Sim::new();
        for f in 0..3 {
            sim.fabric.attach(FpgaId(f), SwitchId(0));
        }
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Tx {
            dst: k(0, 2),
            rows: rows.clone(),
            stream: 0,
        }))
        .unwrap();
        // scatter Block over n_lanes GMI lanes feeding one gather
        let lanes: Vec<Out> = (0..n_lanes as u8).map(|i| Out::tagged(k(0, 3 + i), i)).collect();
        sim.add_kernel(
            k(0, 2),
            FpgaId(0),
            Fifo::new(1 << 20),
            Box::new(GmiKernel::new(GmiOp::Scatter { dsts: lanes, policy: ScatterPolicy::Block })),
        )
        .unwrap();
        for i in 0..n_lanes as u8 {
            sim.add_kernel(
                k(0, 3 + i),
                FpgaId(1),
                Fifo::new(1 << 20),
                Box::new(GmiKernel::new(GmiOp::Forward { dst: Out::tagged(k(0, 10), i) })),
            )
            .unwrap();
        }
        sim.add_kernel(
            k(0, 10),
            FpgaId(1),
            Fifo::new(1 << 20),
            Box::new(GmiKernel::new(GmiOp::Gather { n_srcs: n_lanes, dst: Out::to(k(0, 11)) })),
        )
        .unwrap();
        let got = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(k(0, 11), FpgaId(2), Fifo::new(1 << 20), Box::new(Collect {
            got: got.clone(),
        }))
        .unwrap();
        sim.start();
        sim.run().map_err(|e| e.to_string())?;

        let mut out = got.lock().unwrap().clone();
        out.sort_by_key(|(r, _)| *r);
        prop_assert!(out.len() == n_rows, "lost rows: {} != {}", out.len(), n_rows);
        // Block scatter + rank-ordered gather preserves global row order
        let vals: Vec<Vec<i32>> = out.into_iter().map(|(_, v)| v).collect();
        prop_assert!(vals == rows, "rows reordered or corrupted");
        Ok(())
    });
}

#[test]
fn prop_reduce_equals_element_sum() {
    check_with(&Config { cases: 32, ..Default::default() }, "reduce-sum", |g| {
        let n_srcs = g.usize_in(2, 5);
        let n_rows = g.usize_in(1, 8);
        let width = g.usize_in(1, 6);
        let data: Vec<Vec<Vec<i32>>> = (0..n_srcs)
            .map(|_| {
                (0..n_rows)
                    .map(|_| (0..width).map(|_| g.i64_in(-10_000, 10_000) as i32).collect())
                    .collect()
            })
            .collect();

        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        for (s, rows) in data.iter().enumerate() {
            sim.add_kernel(k(0, 1 + s as u8), FpgaId(0), Fifo::new(1 << 20), Box::new(Tx {
                dst: k(0, 20),
                rows: rows.clone(),
                stream: s as u8,
            }))
            .unwrap();
        }
        sim.add_kernel(
            k(0, 20),
            FpgaId(0),
            Fifo::new(1 << 20),
            Box::new(GmiKernel::new(GmiOp::Reduce {
                n_srcs,
                dst: Out::to(k(0, 21)),
                f: ReduceFn::Sum,
            })),
        )
        .unwrap();
        let got = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(k(0, 21), FpgaId(1), Fifo::new(1 << 20), Box::new(Collect {
            got: got.clone(),
        }))
        .unwrap();
        sim.start();
        sim.run().map_err(|e| e.to_string())?;

        let mut out = got.lock().unwrap().clone();
        out.sort_by_key(|(r, _)| *r);
        prop_assert!(out.len() == n_rows, "reduce emitted {} rows, want {n_rows}", out.len());
        for (r, v) in out {
            for (j, &x) in v.iter().enumerate() {
                let want: i32 = data.iter().map(|src| src[r as usize][j]).sum();
                prop_assert!(x == want, "row {r} col {j}: {x} != {want}");
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Routing invariants over random platforms
// ---------------------------------------------------------------------------

#[test]
fn prop_routing_tables_resolve_every_edge() {
    check_with(&Config { cases: 48, ..Default::default() }, "routing-resolves", |g| {
        let n_clusters = g.usize_in(1, 4);
        let mut spec = PlatformSpec::default();
        let mut next_fpga = 0usize;
        for c in 0..n_clusters as u8 {
            let n_kernels = g.usize_in(1, 6);
            let mut kernels = Vec::new();
            for id in 0..n_kernels as u8 {
                let fpga = FpgaId(next_fpga + g.usize_in(0, 1));
                kernels.push(KernelDecl {
                    id,
                    name: format!("k{id}"),
                    ktype: if id == 0 { KernelType::Gateway } else { KernelType::Compute },
                    fpga,
                    dests: vec![],
                    fifo_bytes: 64,
                });
            }
            next_fpga += 2;
            spec.clusters.push(ClusterSpec { id: c, kernels });
        }
        for f in 0..next_fpga {
            spec.switch_of.insert(FpgaId(f), SwitchId(f / 6));
        }
        // random edges (any kernel to any kernel, any cluster)
        let all: Vec<(u8, u8)> = spec
            .clusters
            .iter()
            .flat_map(|c| c.kernels.iter().map(move |kn| (c.id, kn.id)))
            .collect();
        for _ in 0..g.usize_in(0, 10) {
            let (sc, sk) = *g.pick(&all);
            let (dc, dk) = *g.pick(&all);
            let src = spec
                .clusters
                .iter_mut()
                .find(|c| c.id == sc)
                .unwrap()
                .kernels
                .iter_mut()
                .find(|kn| kn.id == sk)
                .unwrap();
            src.dests.push(k(dc, dk));
        }
        spec.validate().map_err(|e| e.to_string())?;
        let tables = spec.routing_tables().map_err(|e| e.to_string())?;

        // every edge must be routable from the source FPGA's tables
        for c in &spec.clusters {
            for kn in &c.kernels {
                let rt = &tables[&kn.fpga];
                for d in &kn.dests {
                    let mut pkt =
                        Packet::new(k(c.id, kn.id), *d, MsgMeta::default(), Payload::Timing(8));
                    if pkt.inter_cluster {
                        pkt.gmi_dst = Some(d.kernel);
                        pkt.dst = GlobalKernelId::gateway_of(d.cluster);
                    }
                    prop_assert!(
                        rt.route(&pkt).is_ok(),
                        "edge {} -> {} unroutable from {:?}",
                        k(c.id, kn.id),
                        d,
                        kn.fpga
                    );
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Integer-op contracts (mirrors of the hypothesis tests on the python side)
// ---------------------------------------------------------------------------

#[test]
fn prop_requant_monotone_and_bounded() {
    check("requant8-monotone", |g| {
        let m = g.i64_in(1 << 14, (1 << 15) - 1);
        let n = g.i64_in(0, 30) as u32;
        let site = RequantSite { m, n };
        let a = g.i64_in(-1_000_000, 1_000_000);
        let b = g.i64_in(-1_000_000, 1_000_000);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let qa = compute::requant8(lo, site);
        let qb = compute::requant8(hi, site);
        prop_assert!(qa <= qb, "requant not monotone: {lo}->{qa}, {hi}->{qb}");
        prop_assert!((-127..=127).contains(&(qa as i64)), "out of range");
        Ok(())
    });
}

#[test]
fn prop_softmax_row_is_distribution() {
    check_with(&Config { cases: 64, ..Default::default() }, "softmax-distribution", |g| {
        let sm = galapagos_llm::ibert::config::SoftmaxParams {
            q_ln2: 1051,
            q_b: 2052,
            q_c: 2_209_112,
        };
        let n = g.usize_in(1, 64);
        let scores: Vec<i32> = (0..n).map(|_| g.i64_in(-100_000, 100_000) as i32).collect();
        let p = compute::softmax_row(&scores, sm);
        prop_assert!(p.iter().all(|&x| x >= 0), "negative probability");
        let total: i64 = p.iter().map(|&x| x as i64).sum();
        prop_assert!(total <= 127 + n as i64, "sum too large: {total}");
        // argmax preserved
        let am_in = scores.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        let am_out = p.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        prop_assert!(
            p[am_in] == p[am_out],
            "argmax not preserved: in {am_in} out {am_out} ({:?})",
            p
        );
        Ok(())
    });
}

#[test]
fn prop_layernorm_shift_invariant() {
    // LayerNorm(x + c) == LayerNorm(x) up to integer rounding of the mean
    check_with(&Config { cases: 64, ..Default::default() }, "ln-shift-invariance", |g| {
        let ln = galapagos_llm::ibert::config::LayerNormParams { kg: 10 };
        let h = 64;
        let gamma = vec![1i64 << 10; h];
        let beta = vec![0i64; h];
        let x: Vec<i64> = (0..h).map(|_| g.i64_in(-100_000, 100_000)).collect();
        let c = g.i64_in(-1_000_000, 1_000_000);
        let shifted: Vec<i64> = x.iter().map(|&v| v + c).collect();
        let a = compute::layernorm_row(&x, &gamma, &beta, ln);
        let b = compute::layernorm_row(&shifted, &gamma, &beta, ln);
        let max_diff =
            a.iter().zip(&b).map(|(&p, &q)| (p as i64 - q as i64).abs()).max().unwrap();
        prop_assert!(max_diff <= 1, "shift changed LN by {max_diff}");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Placer invariants: completeness, resource fit, description round-trip
// ---------------------------------------------------------------------------

#[test]
fn prop_placer_placements_complete_fit_and_roundtrip() {
    check_with(&Config { cases: 24, ..Default::default() }, "placer-invariants", |g| {
        // random-but-valid encoder shapes on generous random fleets
        let heads = *g.pick(&[6usize, 8, 12, 16]);
        let head_dim = *g.pick(&[32usize, 64]);
        let hidden = heads * head_dim;
        let ffn = hidden * 4;
        let max_seq = *g.pick(&[64usize, 128]);
        let shape = ModelShape { hidden, ffn, heads, max_seq, ffn_split: 1 };

        let n_fpgas = g.usize_in(10, 16);
        let devices: Vec<Device> = (0..n_fpgas)
            .map(|_| if g.bool() { Device::Xczu19eg } else { Device::Xcvc1902 })
            .collect();
        let fleet = Fleet {
            devices,
            fpgas_per_switch: g.usize_in(2, 6),
            util_cap: 0.85,
        };

        let sol = placer::place(&shape, &PeConfig::default(), &fleet, &SearchParams::for_m(max_seq))
            .map_err(|e| format!("place failed for {shape:?}: {e:#}"))?;

        // 1. complete: every kernel assigned exactly once, inside the fleet
        prop_assert!(
            sol.placement.slot_of.len() == sol.graph.n_kernels(),
            "placement misses kernels: {} != {}",
            sol.placement.slot_of.len(),
            sol.graph.n_kernels()
        );
        prop_assert!(
            sol.placement.slot_of.iter().all(|&s| s < fleet.n_slots()),
            "kernel assigned outside the fleet"
        );

        // 2. every occupied device within its FULL ResourceBudget
        let reports = placer::validate::check(&sol.graph, &sol.placement, &fleet)
            .map_err(|e| format!("fit check failed: {e:#}"))?;
        prop_assert!(reports.iter().all(|r| r.fits()), "over-budget slot slipped through");

        // 3. the plan round-trips through BuildDescription-style JSON
        let plan = Plan {
            shape: sol.graph.shape,
            fleet: fleet.clone(),
            placement: sol.placement.clone(),
            predicted: sol.predicted,
        };
        let back = Plan::parse(&plan.to_json().pretty()).map_err(|e| e.to_string())?;
        prop_assert!(back == plan, "plan JSON round-trip changed the placement");

        // 4. the cost model accepts the placement and gives sane numbers
        prop_assert!(
            sol.predicted.t >= sol.predicted.x && sol.predicted.x > 0,
            "nonsense latency estimate: {:?}",
            sol.predicted
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Golden determinism: the coalesced calendar-wheel engine must reproduce
// the reference engine (binary heap, per-row packets) cycle for cycle —
// per-probe arrival series, final time, link traffic, per-kernel stats,
// and (functional mode) the exact output bytes.
// ---------------------------------------------------------------------------

#[derive(Debug, PartialEq)]
struct SimFingerprint {
    probes: Vec<u64>,
    end_time: u64,
    packets: u64,
    flits: u64,
    kstats: Vec<(GlobalKernelId, u64, u64, Option<u64>, Option<u64>)>,
    outputs: Vec<Option<Vec<Vec<i8>>>>,
}

/// Engine variant under test: the pre-optimization heap engine, the
/// sequential wheel engine, or the sharded parallel engine at a given
/// thread count and cut granularity.
#[derive(Clone, Copy)]
enum Engine {
    Reference,
    Threads(usize, galapagos_llm::sim::ShardGranularity),
}

fn run_fingerprint_on(
    cfg: &galapagos_llm::eval::testbed::TestbedConfig,
    engine: Engine,
) -> Result<SimFingerprint, String> {
    let mut tb = galapagos_llm::eval::testbed::build_testbed(cfg).map_err(|e| e.to_string())?;
    match engine {
        Engine::Reference => tb.sim.reference_mode(),
        Engine::Threads(n, g) => {
            tb.sim.set_threads(n);
            tb.sim.granularity = g;
        }
    }
    tb.sim.start();
    tb.sim.run().map_err(|e| e.to_string())?;
    let probes =
        tb.sim.trace.probe_times(tb.sink_id).map(|s| s.to_vec()).unwrap_or_default();
    let mut kstats: Vec<(GlobalKernelId, u64, u64, Option<u64>, Option<u64>)> = tb
        .sim
        .trace
        .kernels()
        .map(|(id, s)| (id, s.rx_packets, s.tx_packets, s.first_rx, s.last_rx))
        .collect();
    kstats.sort_by_key(|e| e.0);
    let sink = tb.sink.lock().unwrap();
    let outputs = (0..cfg.inferences).map(|i| sink.matrix(i)).collect();
    Ok(SimFingerprint {
        probes,
        end_time: tb.sim.time,
        packets: tb.sim.fabric.stats.packets,
        flits: tb.sim.fabric.stats.flits,
        kstats,
        outputs,
    })
}

fn run_fingerprint(
    cfg: &galapagos_llm::eval::testbed::TestbedConfig,
    reference: bool,
) -> Result<SimFingerprint, String> {
    let engine = if reference {
        Engine::Reference
    } else {
        Engine::Threads(1, galapagos_llm::sim::ShardGranularity::PerCluster)
    };
    run_fingerprint_on(cfg, engine)
}

#[test]
fn prop_coalesced_engine_is_cycle_exact_timing() {
    use galapagos_llm::eval::testbed::TestbedConfig;
    use galapagos_llm::ibert::graph::default_slots;
    use galapagos_llm::ibert::kernels::Mode;
    check_with(&Config { cases: 8, ..Default::default() }, "coalesce-golden-timing", |g| {
        let m = *g.pick(&[1usize, 2, 5, 16, 33, 64]);
        let inferences = g.usize_in(1, 3) as u32;
        let interval = *g.pick(&[12u64, 100, 767]);
        let fps = *g.pick(&[2usize, 6]);
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
        cfg.inferences = inferences;
        cfg.interval = interval;
        cfg.fpgas_per_switch = fps;
        // randomly merge some kernels onto other FPGAs so bursts form on
        // edges the paper mapping keeps apart (and vice versa)
        let mut slots = default_slots();
        for _ in 0..g.usize_in(0, 6) {
            let kid = g.usize_in(1, slots.len() - 1);
            slots[kid] = g.usize_in(0, 5);
        }
        cfg.placement = Some(slots);

        let opt = run_fingerprint(&cfg, false)?;
        let refr = run_fingerprint(&cfg, true)?;
        prop_assert!(
            opt == refr,
            "coalesced engine diverged (m={m}, inf={inferences}, interval={interval}): \
             opt end={} ref end={}, opt probes={:?} ref probes={:?}",
            opt.end_time,
            refr.end_time,
            &opt.probes[..opt.probes.len().min(8)],
            &refr.probes[..refr.probes.len().min(8)]
        );
        prop_assert!(
            opt.probes.len() == (m as u32 * inferences) as usize,
            "sink saw {} rows, expected {}",
            opt.probes.len(),
            m as u32 * inferences
        );
        Ok(())
    });
}

#[test]
fn prop_coalesced_engine_is_bit_exact_functional() {
    use galapagos_llm::eval::testbed::TestbedConfig;
    use galapagos_llm::ibert::config::ModelConfig;
    use galapagos_llm::ibert::encoder::encoder_forward_reference;
    use galapagos_llm::ibert::kernels::Mode;
    use galapagos_llm::ibert::weights::{synthetic_input, ModelParams};
    check_with(&Config { cases: 6, ..Default::default() }, "coalesce-golden-functional", |g| {
        let mcfg = ModelConfig { hidden: 96, heads: 12, ffn: 192, max_seq: 32, num_encoders: 1 };
        let seed = g.usize_in(0, 1 << 30) as u64;
        let params = std::sync::Arc::new(ModelParams::synthetic(mcfg, seed));
        let m = *g.pick(&[1usize, 4, 11, 24]);
        let input = synthetic_input(mcfg.hidden, m, g.usize_in(0, 1 << 30) as u64);
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(params.clone()));
        cfg.input = Some(std::sync::Arc::new(input.clone()));
        cfg.interval = *g.pick(&[12u64, 96]);

        let opt = run_fingerprint(&cfg, false)?;
        let refr = run_fingerprint(&cfg, true)?;
        prop_assert!(opt == refr, "functional coalesced run diverged at m={m}");
        // and both must equal the native reference forward bit for bit
        let want = encoder_forward_reference(&params, &input).out;
        prop_assert!(
            opt.outputs[0].as_ref() == Some(&want),
            "simulated encoder output != native reference at m={m}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Parallel golden determinism: the sharded conservative-window engine
// must reproduce the sequential engine's timing fingerprint exactly —
// random placements, both cut granularities, thread counts {2, 4, 8}.
// ---------------------------------------------------------------------------

#[test]
fn prop_parallel_engine_is_trace_identical_timing() {
    use galapagos_llm::eval::testbed::TestbedConfig;
    use galapagos_llm::ibert::graph::default_slots;
    use galapagos_llm::ibert::kernels::Mode;
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 6, ..Default::default() }, "parallel-golden-timing", |g| {
        let m = *g.pick(&[1usize, 2, 7, 24, 48]);
        let encoders = *g.pick(&[1usize, 2, 3]);
        let inferences = g.usize_in(1, 3) as u32;
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
        cfg.encoders = encoders;
        cfg.inferences = inferences;
        cfg.interval = *g.pick(&[12u64, 100]);
        cfg.fpgas_per_switch = *g.pick(&[2usize, 6]);
        // random placements reshape both the shard cut and the lookahead
        let mut slots = default_slots();
        for _ in 0..g.usize_in(0, 6) {
            let kid = g.usize_in(1, slots.len() - 1);
            slots[kid] = g.usize_in(0, 5);
        }
        cfg.placement = Some(slots);

        let seq = run_fingerprint_on(&cfg, Engine::Threads(1, ShardGranularity::PerCluster))?;
        let variants = [
            (2usize, ShardGranularity::PerCluster),
            (4, ShardGranularity::PerFpga),
            (8, ShardGranularity::PerCluster),
        ];
        for &(threads, gran) in &variants {
            let par = run_fingerprint_on(&cfg, Engine::Threads(threads, gran))?;
            prop_assert!(
                par == seq,
                "parallel engine diverged (m={m}, enc={encoders}, threads={threads}, \
                 gran={gran:?}): par end={} seq end={}, par probes={:?} seq probes={:?}",
                par.end_time,
                seq.end_time,
                &par.probes[..par.probes.len().min(8)],
                &seq.probes[..seq.probes.len().min(8)]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_engine_is_bit_exact_functional() {
    use galapagos_llm::eval::testbed::TestbedConfig;
    use galapagos_llm::ibert::config::ModelConfig;
    use galapagos_llm::ibert::kernels::Mode;
    use galapagos_llm::ibert::weights::{synthetic_input, ModelParams};
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 4, ..Default::default() }, "parallel-golden-functional", |g| {
        let mcfg = ModelConfig { hidden: 96, heads: 12, ffn: 192, max_seq: 32, num_encoders: 1 };
        let params =
            std::sync::Arc::new(ModelParams::synthetic(mcfg, g.usize_in(0, 1 << 30) as u64));
        let m = *g.pick(&[1usize, 5, 16]);
        let input = synthetic_input(mcfg.hidden, m, g.usize_in(0, 1 << 30) as u64);
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(params));
        cfg.input = Some(std::sync::Arc::new(input));

        let seq = run_fingerprint_on(&cfg, Engine::Threads(1, ShardGranularity::PerCluster))?;
        let par = run_fingerprint_on(&cfg, Engine::Threads(4, ShardGranularity::PerFpga))?;
        prop_assert!(par == seq, "functional payloads diverged across engines at m={m}");
        prop_assert!(par.outputs[0].is_some(), "functional run produced no output");
        Ok(())
    });
}

/// Serving schedules through the parallel engine: open-loop requests
/// with per-request lengths, overlapping in the pipeline, must yield
/// identical fingerprints at every thread count.
#[test]
fn prop_parallel_engine_matches_on_serving_schedules() {
    use galapagos_llm::eval::testbed::TestbedConfig;
    use galapagos_llm::ibert::kernels::Mode;
    use galapagos_llm::serve::Request;
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 5, ..Default::default() }, "parallel-golden-serving", |g| {
        let n_req = g.usize_in(2, 8);
        let mut t = 0u64;
        let schedule: Vec<Request> = (0..n_req)
            .map(|_| {
                t += g.usize_in(0, 4000) as u64;
                Request { arrival: t, m: g.usize_in(1, 48) as u32 }
            })
            .collect();
        let mut cfg = TestbedConfig::proof_of_concept(48, Mode::Timing);
        cfg.encoders = g.usize_in(1, 3);
        cfg.schedule = Some(std::sync::Arc::new(schedule));

        let seq = run_fingerprint_on(&cfg, Engine::Threads(1, ShardGranularity::PerCluster))?;
        for &threads in &[2usize, 8] {
            let eng = Engine::Threads(threads, ShardGranularity::PerCluster);
            let par = run_fingerprint_on(&cfg, eng)?;
            prop_assert!(par == seq, "serving schedule diverged at threads={threads}");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Pipelining invariant: inferences never reorder through the encoder
// ---------------------------------------------------------------------------

#[test]
fn prop_pipelined_inferences_complete_in_order() {
    use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
    use galapagos_llm::ibert::kernels::Mode;
    check_with(&Config { cases: 10, ..Default::default() }, "pipeline-order", |g| {
        let m = [1usize, 7, 16, 33][g.usize_in(0, 3)];
        let inferences = g.usize_in(2, 4) as u32;
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
        cfg.inferences = inferences;
        let mut tb = build_testbed(&cfg).map_err(|e| e.to_string())?;
        tb.sim.start();
        tb.sim.run().map_err(|e| e.to_string())?;
        let sink = tb.sink.lock().unwrap();
        let mut last = 0u64;
        for i in 0..inferences {
            let &(count, t) = sink
                .arrivals
                .get(&i)
                .ok_or_else(|| format!("inference {i} never completed"))?;
            prop_assert!(count == m as u32, "inference {i}: {count}/{m} rows");
            prop_assert!(t >= last, "inference {i} completed before {}", i.wrapping_sub(1));
            last = t;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Lossy transport: thread-count invariance and exactly-once delivery
// ---------------------------------------------------------------------------

/// Lossy runs are bit-identical at every thread count on multi-shard
/// fleets *without* any sequential fallback: drop decisions come from
/// per-link RNG streams (`link_stream_seed`, keyed by run seed and link
/// endpoints), so the drop sequence each link sees is a function of its
/// own traffic alone, not of the global event interleaving. The drop
/// trace is canonicalized at quiescence, which makes it — and every
/// derived statistic — comparable byte for byte across engines.
#[test]
fn prop_lossy_runs_are_bit_identical_across_thread_counts() {
    use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
    use galapagos_llm::ibert::kernels::Mode;
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 6, ..Default::default() }, "lossy-thread-parity", |g| {
        let m = [4usize, 8, 16][g.usize_in(0, 2)];
        let seed = g.rng.next_u64();
        let drop_p = 0.005 + 0.04 * g.f64_unit();
        let reliable = g.bool();
        let encoders = g.usize_in(1, 2);
        let gran =
            if g.bool() { ShardGranularity::PerCluster } else { ShardGranularity::PerFpga };
        type Fingerprint = (u64, u64, u64, u64, u64, Vec<u64>, u32);
        let run = |threads: usize| -> Result<Fingerprint, String> {
            let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
            cfg.encoders = encoders;
            cfg.inferences = 2;
            cfg.threads = Some(threads);
            cfg.granularity = Some(gran);
            cfg.net.drop_probability = drop_p;
            cfg.net.reliable = reliable;
            cfg.net.seed = seed;
            let mut tb = build_testbed(&cfg).map_err(|e| e.to_string())?;
            tb.sim.start();
            tb.sim.run().map_err(|e| e.to_string())?;
            let sink = tb.sink.lock().unwrap();
            let delivered: u32 = sink.arrivals.values().map(|&(n, _)| n).sum();
            Ok((
                tb.sim.time,
                tb.sim.trace.events_processed,
                tb.sim.fabric.stats.packets,
                tb.sim.fabric.stats.flits,
                tb.sim.fabric.stats.dropped,
                tb.sim.fabric.drop_trace.clone(),
                delivered,
            ))
        };
        let seq = run(1)?;
        let par = run(8)?;
        prop_assert!(
            par == seq,
            "lossy run (p={drop_p:.3}, reliable={reliable}) diverged at 8 threads"
        );
        // and with reliable transport the delivery is always complete
        if reliable {
            prop_assert!(
                seq.6 == 2 * m as u32,
                "reliable lossy run delivered {}/{} rows",
                seq.6,
                2 * m
            );
        }
        Ok(())
    });
}

/// Parallel golden, lossy serving: random placements through the full
/// serving stack with packet loss (and a coin-flip on reliable
/// transport), byte-diffing the serving report, Chrome trace, and
/// metrics stream against `--threads 1` at threads {2, 4, 8} across
/// both cut granularities. This is the property that let the engine
/// drop its lossy sequential fallback.
#[test]
fn prop_parallel_golden_lossy_serving_is_byte_identical() {
    use galapagos_llm::ibert::graph::default_slots;
    use galapagos_llm::serve::{run_serving_with_obs, ServeConfig};
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 3, ..Default::default() }, "parallel-golden-lossy", |g| {
        let encoders = g.usize_in(1, 3);
        let requests = g.usize_in(3, 6);
        let seqs_per_s = 1_000.0 + 4_000.0 * g.f64_unit();
        let seed = g.rng.next_u64();
        let reliable = g.bool();
        let mut slots = default_slots();
        for _ in 0..g.usize_in(0, 4) {
            let kid = g.usize_in(1, slots.len() - 1);
            slots[kid] = g.usize_in(0, 5);
        }
        let mk = |threads: usize, gran: ShardGranularity| {
            let mut cfg = ServeConfig::glue(encoders, requests, seqs_per_s, seed);
            cfg.placement = Some(slots.clone());
            cfg.threads = Some(threads);
            cfg.granularity = Some(gran);
            cfg.drop_probability = 0.02;
            cfg.reliable = reliable;
            cfg.obs.enabled = true;
            cfg
        };
        let (r1, o1) =
            run_serving_with_obs(&mk(1, ShardGranularity::PerCluster)).map_err(|e| e.to_string())?;
        let variants = [
            (2usize, ShardGranularity::PerCluster),
            (4, ShardGranularity::PerFpga),
            (8, ShardGranularity::PerCluster),
            (8, ShardGranularity::PerFpga),
        ];
        for &(threads, gran) in &variants {
            let (rn, on) = run_serving_with_obs(&mk(threads, gran)).map_err(|e| e.to_string())?;
            prop_assert!(
                rn.to_json().pretty() == r1.to_json().pretty(),
                "lossy serving report diverged at threads={threads} gran={gran:?} \
                 (reliable={reliable})"
            );
            prop_assert!(
                on.trace_json == o1.trace_json,
                "lossy Chrome trace diverged at threads={threads} gran={gran:?}"
            );
            prop_assert!(
                on.metrics_jsonl == o1.metrics_jsonl,
                "lossy metrics stream diverged at threads={threads} gran={gran:?}"
            );
        }
        Ok(())
    });
}

/// Parallel golden, failover serving: a §6 mid-serving FPGA outage with
/// recovery re-placement, run through the phased sharded engine at
/// threads {2, 4, 8} on random placements and both granularities, must
/// reproduce the sequential report/trace/telemetry byte for byte —
/// including the fault section (time-to-recover, buffered packets,
/// re-placed kernels).
#[test]
fn prop_parallel_golden_failover_is_byte_identical() {
    use galapagos_llm::eval::testbed::FailureSchedule;
    use galapagos_llm::ibert::graph::default_slots;
    use galapagos_llm::serve::{run_serving_with_obs, ServeConfig};
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 3, ..Default::default() }, "parallel-golden-failover", |g| {
        let encoders = g.usize_in(1, 3);
        let requests = g.usize_in(3, 6);
        let seqs_per_s = 1_000.0 + 4_000.0 * g.f64_unit();
        let seed = g.rng.next_u64();
        let mut slots = default_slots();
        for _ in 0..g.usize_in(0, 4) {
            let kid = g.usize_in(1, slots.len() - 1);
            slots[kid] = g.usize_in(0, 5);
        }
        // kill a board that actually hosts kernels under this placement
        let per = slots.iter().copied().max().unwrap() + 1;
        let fail = FailureSchedule {
            fpga: per * g.usize_in(0, encoders - 1) + *g.pick(&slots[1..]),
            at_cycle: g.usize_in(50_000, 400_000) as u64,
            recovery_cycles: Some(g.usize_in(50_000, 200_000) as u64),
        };
        let mk = |threads: usize, gran: ShardGranularity| {
            let mut cfg = ServeConfig::glue(encoders, requests, seqs_per_s, seed);
            cfg.placement = Some(slots.clone());
            cfg.threads = Some(threads);
            cfg.granularity = Some(gran);
            cfg.fail = Some(fail.clone());
            cfg.obs.enabled = true;
            cfg
        };
        let (r1, o1) =
            run_serving_with_obs(&mk(1, ShardGranularity::PerCluster)).map_err(|e| e.to_string())?;
        let variants = [
            (2usize, ShardGranularity::PerFpga),
            (4, ShardGranularity::PerCluster),
            (8, ShardGranularity::PerFpga),
            (8, ShardGranularity::PerCluster),
        ];
        for &(threads, gran) in &variants {
            let (rn, on) = run_serving_with_obs(&mk(threads, gran)).map_err(|e| e.to_string())?;
            prop_assert!(
                rn.to_json().pretty() == r1.to_json().pretty(),
                "failover serving report diverged at threads={threads} gran={gran:?} \
                 (fail at {}, recover {:?})",
                fail.at_cycle,
                fail.recovery_cycles
            );
            prop_assert!(
                on.trace_json == o1.trace_json,
                "failover Chrome trace diverged at threads={threads} gran={gran:?}"
            );
            prop_assert!(
                on.metrics_jsonl == o1.metrics_jsonl,
                "failover metrics stream diverged at threads={threads} gran={gran:?}"
            );
        }
        Ok(())
    });
}

/// Parallel golden, autoregressive decode: the feedback loop (sink →
/// gateway virtual → source re-injection, one pass per generated token)
/// through the sharded engine at threads {2, 4, 8} on random placements
/// and both granularities must reproduce the sequential v4 report,
/// Chrome trace, and metrics stream byte for byte — with a coin-flip on
/// lossy reliable transport, so retransmitted feedback rows are covered
/// too.
#[test]
fn prop_parallel_golden_decode_is_byte_identical() {
    use galapagos_llm::ibert::graph::default_slots;
    use galapagos_llm::serve::{run_serving_with_obs, DecodeConfig, ServeConfig};
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 3, ..Default::default() }, "parallel-golden-decode", |g| {
        let encoders = g.usize_in(1, 3);
        let requests = g.usize_in(3, 6);
        let seqs_per_s = 1_000.0 + 4_000.0 * g.f64_unit();
        let seed = g.rng.next_u64();
        let max_new = g.usize_in(2, 4) as u32;
        let lossy = g.bool();
        let mut slots = default_slots();
        for _ in 0..g.usize_in(0, 4) {
            let kid = g.usize_in(1, slots.len() - 1);
            slots[kid] = g.usize_in(0, 5);
        }
        let mk = |threads: usize, gran: ShardGranularity| {
            let mut cfg = ServeConfig::glue(encoders, requests, seqs_per_s, seed);
            cfg.decode = Some(DecodeConfig { max_new_tokens: max_new });
            cfg.placement = Some(slots.clone());
            cfg.threads = Some(threads);
            cfg.granularity = Some(gran);
            if lossy {
                cfg.drop_probability = 0.02;
                cfg.reliable = true;
            }
            cfg.obs.enabled = true;
            cfg
        };
        let (r1, o1) =
            run_serving_with_obs(&mk(1, ShardGranularity::PerCluster)).map_err(|e| e.to_string())?;
        prop_assert!(r1.schema() == "serving_report/v4", "decode run must report v4");
        if lossy {
            // reliable transport: every prefill AND every token pass lands
            prop_assert!(
                r1.completed == requests,
                "reliable decode completed {}/{requests} requests",
                r1.completed
            );
        }
        let variants = [
            (2usize, ShardGranularity::PerCluster),
            (4, ShardGranularity::PerFpga),
            (8, ShardGranularity::PerCluster),
            (8, ShardGranularity::PerFpga),
        ];
        for &(threads, gran) in &variants {
            let (rn, on) = run_serving_with_obs(&mk(threads, gran)).map_err(|e| e.to_string())?;
            prop_assert!(
                rn.to_json().pretty() == r1.to_json().pretty(),
                "decode serving report diverged at threads={threads} gran={gran:?} \
                 (n={max_new}, lossy={lossy})"
            );
            prop_assert!(
                on.trace_json == o1.trace_json,
                "decode Chrome trace diverged at threads={threads} gran={gran:?}"
            );
            prop_assert!(
                on.metrics_jsonl == o1.metrics_jsonl,
                "decode metrics stream diverged at threads={threads} gran={gran:?}"
            );
        }
        Ok(())
    });
}

/// Parallel golden, continuous batching: the iteration-level scheduler
/// (windowed batch assembly, marginal-cost token rows, finished
/// sequences exiting while queued prefills join mid-stream) through the
/// sharded engine at threads {2, 4, 8} on random placements and both
/// granularities must reproduce the sequential v5 report, Chrome trace,
/// and metrics stream byte for byte.
#[test]
fn prop_parallel_golden_batching_is_byte_identical() {
    use galapagos_llm::ibert::graph::default_slots;
    use galapagos_llm::serve::{run_serving_with_obs, BatchConfig, DecodeConfig, ServeConfig};
    use galapagos_llm::sim::ShardGranularity;
    check_with(&Config { cases: 3, ..Default::default() }, "parallel-golden-batching", |g| {
        let requests = g.usize_in(4, 8);
        let seqs_per_s = 4_000.0 + 16_000.0 * g.f64_unit();
        let seed = g.rng.next_u64();
        let max_new = g.usize_in(2, 5) as u32;
        let batch_max = *g.pick(&[2u32, 4, 8]);
        let window = *g.pick(&[64u64, 256, 1024]);
        let mut slots = default_slots();
        for _ in 0..g.usize_in(0, 4) {
            let kid = g.usize_in(1, slots.len() - 1);
            slots[kid] = g.usize_in(0, 5);
        }
        let mk = |threads: usize, gran: ShardGranularity| {
            let mut cfg = ServeConfig::glue(1, requests, seqs_per_s, seed);
            cfg.decode = Some(DecodeConfig { max_new_tokens: max_new });
            cfg.batching = Some(BatchConfig { max: batch_max, window });
            cfg.placement = Some(slots.clone());
            cfg.threads = Some(threads);
            cfg.granularity = Some(gran);
            cfg.obs.enabled = true;
            cfg
        };
        let (r1, o1) =
            run_serving_with_obs(&mk(1, ShardGranularity::PerCluster)).map_err(|e| e.to_string())?;
        prop_assert!(r1.schema() == "serving_report/v5", "batched run must report v5");
        prop_assert!(
            r1.completed == requests,
            "batched run completed {}/{requests} requests",
            r1.completed
        );
        let b = r1.batching.as_ref().ok_or("v5 report missing batching section")?;
        prop_assert!(
            b.histogram.iter().enumerate().map(|(i, &n)| (i as u64 + 1) * n).sum::<u64>()
                == requests as u64 * max_new as u64,
            "released batches must carry every generated token exactly once"
        );
        let variants = [
            (2usize, ShardGranularity::PerCluster),
            (4, ShardGranularity::PerFpga),
            (8, ShardGranularity::PerCluster),
            (8, ShardGranularity::PerFpga),
        ];
        for &(threads, gran) in &variants {
            let (rn, on) = run_serving_with_obs(&mk(threads, gran)).map_err(|e| e.to_string())?;
            prop_assert!(
                rn.to_json().pretty() == r1.to_json().pretty(),
                "batched serving report diverged at threads={threads} gran={gran:?} \
                 (B={batch_max}, W={window}, n={max_new})"
            );
            prop_assert!(
                on.trace_json == o1.trace_json,
                "batched Chrome trace diverged at threads={threads} gran={gran:?}"
            );
            prop_assert!(
                on.metrics_jsonl == o1.metrics_jsonl,
                "batched metrics stream diverged at threads={threads} gran={gran:?}"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Telemetry determinism: the observability artifacts (Chrome trace,
// metrics stream, v3 report) are part of the bit-identical contract,
// and collecting them never perturbs the simulation itself.
// ---------------------------------------------------------------------------

#[test]
fn prop_telemetry_artifacts_are_deterministic_and_inert() {
    use galapagos_llm::serve::{run_serving, run_serving_with_obs, ServeConfig};
    check_with(&Config { cases: 4, ..Default::default() }, "telemetry-determinism", |g| {
        let encoders = g.usize_in(1, 3);
        let requests = g.usize_in(3, 8);
        let seqs_per_s = 1_000.0 + 4_000.0 * g.f64_unit();
        let seed = g.rng.next_u64();
        let lossy = g.bool();
        let mk = |threads: usize, obs: bool| {
            let mut cfg = ServeConfig::glue(encoders, requests, seqs_per_s, seed);
            cfg.threads = Some(threads);
            cfg.obs.enabled = obs;
            if lossy {
                cfg.drop_probability = 0.01;
                cfg.reliable = true;
            }
            cfg
        };

        let (r1, obs1) = run_serving_with_obs(&mk(1, true)).map_err(|e| e.to_string())?;
        let threads = *g.pick(&[2usize, 4, 8]);
        let (rn, obsn) = run_serving_with_obs(&mk(threads, true)).map_err(|e| e.to_string())?;
        prop_assert!(
            obsn.trace_json == obs1.trace_json,
            "Chrome trace diverged at threads={threads} (lossy={lossy})"
        );
        prop_assert!(
            obsn.metrics_jsonl == obs1.metrics_jsonl,
            "metrics stream diverged at threads={threads} (lossy={lossy})"
        );
        prop_assert!(
            rn.to_json().pretty() == r1.to_json().pretty(),
            "v3 serving report diverged at threads={threads} (lossy={lossy})"
        );

        // inert collection: stripping the v3 sections recovers the
        // telemetry-off report byte for byte
        let off = run_serving(&mk(1, false)).map_err(|e| e.to_string())?;
        prop_assert!(off.schema() == "serving_report/v2", "off-report must stay v2");
        let mut stripped = r1;
        stripped.telemetry = None;
        stripped.sim_profile = None;
        prop_assert!(
            stripped.to_json().pretty() == off.to_json().pretty(),
            "telemetry collection perturbed the simulation (lossy={lossy})"
        );
        Ok(())
    });
}
