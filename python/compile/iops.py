"""Integer-only I-BERT operators (L2), written in jnp.

Every op here consumes/produces integers only; all float->int constant
folding happened in quantize.py at build time.  The rust coordinator
(rust/src/ibert/compute.rs) mirrors these functions operation-for-operation;
bit-exactness is enforced by golden vectors exported by weights.py.

Semantics contract shared with rust:
  * floor_div(a, b)  == jnp.floor_divide == rust i64::div_euclid (b > 0)
  * rshift_round(x, n) == (x + 2^(n-1)) >> n, arithmetic shift (i64)
  * all intermediates fit in int64 (ranges documented per op)
"""

from __future__ import annotations

import jax.numpy as jnp

from . import quantize as qz
from .quantize import EncoderQuant, GeluParams, LayerNormParams, RequantSite, SoftmaxParams

I64 = jnp.int64
I32 = jnp.int32
I8 = jnp.int8


def floor_div(a, b):
    return jnp.floor_divide(a, b)


def rshift_round(x, n: int):
    """Round-half-up right shift; n is a static python int >= 0."""
    if n == 0:
        return x
    return (x + (1 << (n - 1))) >> n


def clip8(x):
    return jnp.clip(x, -127, 127).astype(I8)


def requant8(acc, site: RequantSite):
    """int32/int64 accumulator -> int8 at site.out_scale."""
    return clip8(rshift_round(acc.astype(I64) * site.m, site.n))


def requant32(acc, site: RequantSite):
    """int32/int64 accumulator -> int64 value at site.out_scale (no clip).

    Used for the residual/LayerNorm domain, which stays wide.
    """
    return rshift_round(acc.astype(I64) * site.m, site.n)


def isqrt(n):
    """Element-wise floor integer sqrt of non-negative int64.

    Fixed-iteration Newton so it lowers to straight-line HLO (no dynamic
    loop): 35 iterations from 2^32 covers any n < 2^63, then two
    floor-corrections.  Rust mirrors the exact same schedule.
    """
    n = n.astype(I64)
    x = jnp.where(n > 0, jnp.int64(1) << 32, jnp.int64(1))
    for _ in range(qz.ISQRT_ITERS):
        x = jnp.maximum(floor_div(x + floor_div(n, jnp.maximum(x, 1)), 2), 1)
    x = jnp.where(x * x > n, x - 1, x)
    x = jnp.where(x * x > n, x - 1, x)
    return jnp.where(n == 0, jnp.int64(0), x)


def linear_acc(x_i8, w_i8, b_i32):
    """int8 x int8 -> int32 accumulator matmul + int32 bias (the PE array).

    x: [M, K] int8, w: [K, N] int8, b: [N] int32 (at acc scale).
    This is the plain-jnp path; model.py swaps in the pallas kernel (L1).
    """
    acc = jnp.matmul(
        x_i8.astype(I32), w_i8.astype(I32), preferred_element_type=I32
    )
    return acc + b_i32[None, :].astype(I32)


def i_softmax(scores_i32, sm: SoftmaxParams, valid_mask=None):
    """Integer softmax over the last axis of int32 scores.

    scores value = q * sm.scale (1/sqrt(d_k) already folded into the scale).
    valid_mask: optional bool [..., M]; padded columns get probability 0
    (this is how the fixed-shape AOT artifact reproduces the no-padding
    hardware results on short sequences).
    Returns int8 probabilities with scale 1/127.
    """
    q = scores_i32.astype(I64)
    if valid_mask is not None:
        neg = jnp.int64(-(1 << 40))
        q = jnp.where(valid_mask, q, neg)
    qmax = q.max(axis=-1, keepdims=True)
    qt = q - qmax  # <= 0
    z = floor_div(-qt, sm.q_ln2)
    p = qt + z * sm.q_ln2  # in (-q_ln2, 0]
    e = (p + sm.q_b) ** 2 + sm.q_c  # >= 0, <~ (q_b + q_ln2)^2 + q_c
    zc = jnp.minimum(z, qz.EXP_SHIFT_MAX).astype(I64)
    e = jnp.right_shift(e, zc)
    if valid_mask is not None:
        e = jnp.where(valid_mask, e, jnp.int64(0))
    total = jnp.maximum(e.sum(axis=-1, keepdims=True), 1)
    q15 = floor_div(e << qz.SOFTMAX_OUT_SHIFT, total)
    p8 = rshift_round(q15 * qz.SOFTMAX_OUT_SCALE, qz.SOFTMAX_OUT_SHIFT)
    return jnp.clip(p8, 0, 127).astype(I8)


def i_gelu(q_i8, gp: GeluParams):
    """Integer GELU on int8 input at gp.scale; int8 output at gp.out.out_scale.

    I-BERT Alg. 2/3: erf(x) ~ sgn(x)[a(clip(|x|,max=-b)+b)^2 + 1].
    s_erf = a*(s/sqrt2)^2 is negative, so the output integer is negated
    before the (positive-factor) dyadic requantiser.
    """
    q = q_i8.astype(I64)
    sgn = jnp.sign(q)
    qa = jnp.minimum(jnp.abs(q), -gp.q_b)
    poly = (qa + gp.q_b) ** 2 + gp.q_c
    q_erf = sgn * poly
    q_out = q * (q_erf + gp.q_one)
    return requant8(-q_out, gp.out)


def i_layernorm(q_wide, gamma_q, beta_q, ln: LayerNormParams):
    """Integer LayerNorm over the last axis (hidden dim H).

    q_wide: int64 values in the residual domain (scale ln.in_scale).
    gamma_q/beta_q: int64 [H] fixed-point Q{ln.kg} constants from quantize.py.
    Returns int8 at ln.out_scale.
    """
    q = q_wide.astype(I64)
    h = q.shape[-1]
    sum_q = q.sum(axis=-1, keepdims=True)
    mean = floor_div(2 * sum_q + h, 2 * h)
    d = q - mean
    var = floor_div((d * d).sum(axis=-1, keepdims=True), h)
    std = jnp.maximum(isqrt(var), 1)
    t = floor_div(d * gamma_q[None, :], std) + beta_q[None, :]
    return clip8(rshift_round(t, ln.kg))


def head_split(x, heads: int):
    """[M, H] -> [heads, M, H/heads]"""
    m, hdim = x.shape
    return jnp.transpose(x.reshape(m, heads, hdim // heads), (1, 0, 2))


def head_merge(x):
    """[heads, M, d] -> [M, heads*d]"""
    heads, m, d = x.shape
    return jnp.transpose(x, (1, 0, 2)).reshape(m, heads * d)
