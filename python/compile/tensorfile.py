"""GTF1: the tiny binary tensor format shared between Python and Rust.

Layout (little endian):
    magic   4 bytes  b"GTF1"
    dtype   u8       0=int8, 1=int32, 2=int64, 3=float32
    ndim    u8
    pad     2 bytes  zero
    dims    ndim * u32
    data    raw little-endian, C order

The rust twin lives in rust/src/util/tensorfile.rs; both sides have
round-trip tests and the integration tests read each other's files.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"GTF1"

_DTYPES = {
    0: np.dtype("<i1"),
    1: np.dtype("<i4"),
    2: np.dtype("<i8"),
    3: np.dtype("<f4"),
}
_CODES = {v: k for k, v in _DTYPES.items()}


def write_tensor(path: str, arr: np.ndarray) -> None:
    # NB: np.ascontiguousarray would silently promote 0-d arrays to 1-d.
    arr = np.asarray(arr, order="C")
    code = _CODES.get(arr.dtype.newbyteorder("<"))
    if code is None:
        raise ValueError(f"unsupported dtype {arr.dtype}")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<BBH", code, arr.ndim, 0))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.astype(_DTYPES[code]).tobytes())


def read_tensor(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        code, ndim, _ = struct.unpack("<BBH", f.read(4))
        dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
        dt = _DTYPES[code]
        data = f.read()
    n = int(np.prod(dims)) if ndim else 1
    arr = np.frombuffer(data, dtype=dt, count=n)
    return arr.reshape(dims)
