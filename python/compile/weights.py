"""Model File System generator (paper §6.1) + golden vectors.

The paper's Cluster Builder extracts PyTorch module parameters into a local
file system consumed by the layer handlers.  Our equivalent: seeded
synthetic weights (DESIGN.md substitution for the offline HF checkpoint),
quantised once, written as GTF1 tensors + quantparams.json.  Both the JAX
model (L2) and the rust coordinator (L3) read this file system — rust never
re-derives a constant from floats.

Golden vectors pin the bit-exact contract: per-stage tensors at M=128 and
final outputs at several sequence lengths, produced by the plain-jnp
reference path.
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from . import quantize as qz
from .model import EncoderParams, encoder_fwd, model_fwd
from .tensorfile import write_tensor

SEED = 20240601
GOLDEN_LENS = [1, 8, 38, 64, 128]
STAGE_KEYS = ["q", "k", "v", "probs", "att", "res", "ln1", "gelu_in", "mid", "res2", "out"]


def build_params(seed: int = SEED):
    w = qz.EncoderWeights.generate(seed)
    eq = qz.calibrate(w)
    return w, eq, EncoderParams.from_weights(w, eq)


def golden_input(m: int, eq, seed: int = SEED + 1) -> np.ndarray:
    """Synthetic GLUE-like activations: unit-normal floats quantised at s_in."""
    rng = np.random.default_rng(seed)
    xf = rng.normal(0.0, 1.0, size=(m, qz.HIDDEN))
    return np.clip(np.round(xf / eq.s_in), -127, 127).astype(np.int8)


def export(outdir: str, seed: int = SEED) -> dict:
    os.makedirs(outdir, exist_ok=True)
    wdir = os.path.join(outdir, "weights")
    gdir = os.path.join(outdir, "goldens")
    os.makedirs(wdir, exist_ok=True)
    os.makedirs(gdir, exist_ok=True)

    w, eq, p = build_params(seed)

    manifest: dict = {
        "seed": seed,
        "hidden": qz.HIDDEN,
        "heads": qz.HEADS,
        "ffn": qz.FFN,
        "max_seq": qz.MAX_SEQ,
        "num_encoders": qz.NUM_ENCODERS,
        "weights": {},
        "goldens": {},
        "artifacts": {},
    }

    # --- model file system: quantised parameters ---
    for name, arr in p.weight_arrays():
        path = os.path.join("weights", f"{name}.bin")
        write_tensor(os.path.join(outdir, path), arr)
        manifest["weights"][name] = {"file": path, "shape": list(arr.shape),
                                     "dtype": str(arr.dtype)}

    with open(os.path.join(outdir, "quantparams.json"), "w") as f:
        f.write(qz.quantparams_to_json(eq))

    # --- goldens: stage tensors at M=128 (reference path) ---
    x128 = golden_input(qz.MAX_SEQ, eq)
    mask128 = np.ones(qz.MAX_SEQ, dtype=bool)
    out, stages = encoder_fwd(p, jnp.asarray(x128), jnp.asarray(mask128),
                              use_pallas=False, collect_stages=True)
    write_tensor(os.path.join(gdir, "input_m128.bin"), x128)
    manifest["goldens"]["input_m128"] = "goldens/input_m128.bin"
    for k in STAGE_KEYS:
        arr = np.asarray(stages[k])
        if k == "probs":  # [A, M, M] int8
            pass
        fn = f"stage_{k}_m128.bin"
        write_tensor(os.path.join(gdir, fn), arr)
        manifest["goldens"][f"stage_{k}_m128"] = f"goldens/{fn}"

    # --- goldens: encoder output at several sequence lengths (no padding) ---
    for m in GOLDEN_LENS:
        xm = x128[:m]
        maskm = np.ones(m, dtype=bool)
        om = np.asarray(encoder_fwd(p, jnp.asarray(xm), jnp.asarray(maskm),
                                    use_pallas=False))
        fn = f"encoder_out_m{m}.bin"
        write_tensor(os.path.join(gdir, fn), om)
        manifest["goldens"][f"encoder_out_m{m}"] = f"goldens/{fn}"

    # --- golden: full 12-encoder model at the GLUE average length ---
    m = 38
    om = np.asarray(model_fwd(p, jnp.asarray(x128[:m]), jnp.asarray(np.ones(m, bool)),
                              qz.NUM_ENCODERS, use_pallas=False))
    write_tensor(os.path.join(gdir, "model12_out_m38.bin"), om)
    manifest["goldens"]["model12_out_m38"] = "goldens/model12_out_m38.bin"

    return manifest


def write_manifest(outdir: str, manifest: dict) -> None:
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
