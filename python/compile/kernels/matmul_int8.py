"""Tiled INT8 matmul Pallas kernel — the paper's Tile/PE array, re-thought
for a TPU-style memory hierarchy (DESIGN.md §Hardware-Adaptation).

Mapping from the paper's HLS design (Fig. 11):
  * a *Tile* owns a slab of weight columns kept in BRAM  ->  a grid step `j`
    owns a (K, BN) weight block kept resident in VMEM (weight-stationary);
  * the *PE array* doing partial dot-products on streamed rows  ->  the MXU
    dot_general on an (BM, K) input block streamed HBM->VMEM per grid step;
  * AXIS row streaming  ->  BlockSpec index_map (i, 0) walking input rows;
  * INT8xINT8 -> INT32 accumulate  ->  preferred_element_type=jnp.int32.

The same kernel serves all three matmul shapes of the encoder (Linear
768x768 / 768x3072 / 3072x768, per-head QK^T 64-dim, and softmax-MM MxM by
64), exactly like the paper reuses its PE design across modules.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU efficiency is estimated from the block shapes (see
`vmem_bytes` / `mxu_utilization` and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I8 = jnp.int8
I32 = jnp.int32


def _mm_kernel(x_ref, w_ref, b_ref, o_ref):
    """One (BM, BN) output block: full-K dot product plus bias row."""
    acc = jax.lax.dot_general(
        x_ref[...].astype(I32),
        w_ref[...].astype(I32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=I32,
    )
    o_ref[...] = acc + b_ref[...][None, :]


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul_int8(x, w, b=None, *, bm: int = 32, bn: int = 128):
    """int8[M,K] @ int8[K,N] + int32[N] -> int32[M,N] via the Pallas kernel.

    Ragged M/N are zero-padded up to the block grid and sliced back —
    the software analogue of the paper's minimum-padding PE feed (§7.1.2).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if b is None:
        b = jnp.zeros((n,), I32)
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    xp = _pad_to(x, 0, bm)
    wp = _pad_to(w, 1, bn)
    bp = _pad_to(b, 0, bn)
    mp, np_ = xp.shape[0], wp.shape[1]
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), I32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, k: int) -> int:
    """Per-step VMEM residency of the kernel (int8 x, int8 w, i32 bias+out)."""
    return bm * k + k * bn + 4 * bn + 4 * bm * bn


def mxu_utilization(bm: int, bn: int, k: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes busy for a (bm, k) x (k, bn) block matmul.

    The MXU is a mxu x mxu systolic array; utilisation is limited by how
    well each GEMM dimension fills its lanes.
    """

    def fill(d):
        full, rem = divmod(d, mxu)
        lanes = full * mxu + rem
        steps = full + (1 if rem else 0)
        return lanes / (steps * mxu)

    return fill(bm) * fill(bn) * fill(k)
