"""Pure-jnp oracle for the L1 kernels — the CORE correctness signal.

`matmul_int8_ref` is the reference the Pallas kernel is checked against in
python/tests/test_kernels.py (hypothesis sweeps shapes/dtypes).  It is also
what the AOT encoder uses when built with `use_pallas=False`, giving an
independent second lowering of the whole model.
"""

from __future__ import annotations

import jax.numpy as jnp

I32 = jnp.int32


def matmul_int8_ref(x, w, b=None):
    """int8[M,K] @ int8[K,N] + int32[N] -> int32[M,N], plain jnp."""
    acc = jnp.matmul(x.astype(I32), w.astype(I32), preferred_element_type=I32)
    if b is not None:
        acc = acc + b[None, :].astype(I32)
    return acc
