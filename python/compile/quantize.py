"""Quantisation parameter derivation — the single source of truth.

I-BERT (Kim et al., ICML'21) is integer-only at inference time: every float
scale is folded into integer constants at *build* time.  This module

  1. generates seeded synthetic encoder weights (no network access to the
     Hugging Face checkpoint the paper used — see DESIGN.md substitutions),
  2. runs a float calibration pass to pick activation scales,
  3. derives every integer constant the runtime needs (dyadic requantisers,
     i-GELU / i-Softmax / i-LayerNorm polynomial constants),
  4. packages them in `QuantParams`, serialised to artifacts/quantparams.json
     (+ .bin tensors) and consumed by BOTH the JAX model (L2) and the rust
     coordinator (L3).

Deriving constants in exactly one place is what makes the three
implementations (pallas/jnp/rust) bit-exact: rust never re-does float math.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

import numpy as np

# Model geometry: I-BERT base == BERT-base (L=12, A=12, H=768), GLUE max len.
HIDDEN = 768
HEADS = 12
HEAD_DIM = HIDDEN // HEADS  # 64
FFN = 4 * HIDDEN  # 3072
MAX_SEQ = 128
NUM_ENCODERS = 12

# i-GELU / i-exp polynomial coefficients, from the I-BERT paper (Sec. 3.3/3.4)
GELU_A = -0.2888
GELU_B = -1.769
EXP_A = 0.3585
EXP_B = 1.353
EXP_C = 0.344
LN2 = math.log(2.0)

SOFTMAX_OUT_SHIFT = 15  # softmax probabilities are produced in Q15 then
SOFTMAX_OUT_SCALE = 127  # requantised to int8 with scale 1/127
EXP_SHIFT_MAX = 31  # clamp on the 2^-z shift in i-exp
ISQRT_ITERS = 35  # fixed Newton iterations in integer sqrt (straight-line HLO)
LN_KG = 10  # layernorm gamma/beta fixed-point bits
REQUANT_BITS = 15  # dyadic multiplier magnitude (m < 2^15)


def dyadic(factor: float, bits: int = REQUANT_BITS) -> tuple[int, int]:
    """Approximate `factor` as m / 2**n with 2**(bits-1) <= m < 2**bits.

    The classic dyadic-number trick from integer-only inference: a float
    rescale becomes one integer multiply plus an arithmetic shift.
    """
    if factor <= 0:
        raise ValueError(f"dyadic factor must be positive, got {factor}")
    n = 0
    m = factor
    while m < 2 ** (bits - 1):
        m *= 2
        n += 1
    while m >= 2**bits:
        m /= 2
        n -= 1
    if n < 0:
        raise ValueError(f"factor {factor} too large for dyadic({bits})")
    return int(round(m)), n


@dataclass
class RequantSite:
    """One int32 -> int8 (or int32) rescale: q_out = clip((q*m + r) >> n)."""

    m: int
    n: int
    in_scale: float
    out_scale: float

    @classmethod
    def make(cls, in_scale: float, out_scale: float) -> "RequantSite":
        m, n = dyadic(in_scale / out_scale)
        return cls(m=m, n=n, in_scale=in_scale, out_scale=out_scale)

    def to_json(self):
        return {"m": self.m, "n": self.n, "in_scale": self.in_scale, "out_scale": self.out_scale}

    @classmethod
    def from_json(cls, d):
        return cls(m=d["m"], n=d["n"], in_scale=d["in_scale"], out_scale=d["out_scale"])


@dataclass
class SoftmaxParams:
    """Integer constants for i-Softmax over int32 scores of scale `scale`."""

    scale: float  # score scale (already includes the 1/sqrt(d_k) fold)
    q_ln2: int
    q_b: int
    q_c: int

    @classmethod
    def make(cls, scale: float) -> "SoftmaxParams":
        return cls(
            scale=scale,
            q_ln2=max(1, math.floor(LN2 / scale)),
            q_b=math.floor(EXP_B / scale),
            q_c=math.floor(EXP_C / (EXP_A * scale * scale)),
        )

    def to_json(self):
        return self.__dict__

    @classmethod
    def from_json(cls, d):
        return cls(**d)


@dataclass
class GeluParams:
    """Integer constants for i-GELU over int8 values of scale `scale`."""

    scale: float
    q_b: int  # floor(B / s'), s' = scale/sqrt2            (negative)
    q_c: int  # floor(1 / s_erf), s_erf = A*s'^2           (negative)
    q_one: int  # == floor(1 / s_erf); kept separate to mirror I-BERT Alg. 3
    out: RequantSite  # |scale * s_erf / 2| -> s_out requantiser (sign
    # flipped in the ops because s_erf < 0; see iops.i_gelu)

    @classmethod
    def make(cls, scale: float, out_scale: float) -> "GeluParams":
        s = scale / math.sqrt(2.0)
        s_erf = GELU_A * s * s  # negative
        q_b = math.floor(GELU_B / s)
        q_c = math.floor(1.0 / s_erf)
        q_one = math.floor(1.0 / s_erf)
        pre = scale * abs(s_erf) / 2.0
        return cls(scale=scale, q_b=q_b, q_c=q_c, q_one=q_one, out=RequantSite.make(pre, out_scale))

    def to_json(self):
        d = dict(self.__dict__)
        d["out"] = self.out.to_json()
        return d

    @classmethod
    def from_json(cls, d):
        d = dict(d)
        d["out"] = RequantSite.from_json(d["out"])
        return cls(**d)


@dataclass
class LayerNormParams:
    """Integer constants for i-LayerNorm: per-channel gamma_q/beta_q in Q{kg}.

    q_out = clip(rshift_round(floor_div(d*gamma_q, std) + beta_q, kg))
    where d = q - mean(q), std = isqrt(sum(d^2)/H).
    """

    kg: int
    in_scale: float
    out_scale: float
    # gamma_q / beta_q live in tensorfiles (per-channel int32); names only here
    gamma_file: str = ""
    beta_file: str = ""

    def to_json(self):
        return self.__dict__

    @classmethod
    def from_json(cls, d):
        return cls(**d)


@dataclass
class EncoderQuant:
    """All quantisation constants for one encoder layer."""

    s_in: float
    s_q: float
    s_k: float
    s_v: float
    s_probs: float
    s_att: float
    s_res: float
    s_ln1: float
    s_gelu_in: float
    s_mid: float
    s_res2: float
    s_out: float

    rq_q: RequantSite = None  # acc(s_in*s_wq) -> s_q
    rq_k: RequantSite = None
    rq_v: RequantSite = None
    rq_att: RequantSite = None  # acc(s_probs*s_v) -> s_att
    rq_proj: RequantSite = None  # acc(s_att*s_wo) -> s_res (stays int32)
    rq_resin: RequantSite = None  # s_in -> s_res (int8 -> int32 path)
    rq_gelu_in: RequantSite = None  # acc(s_ln1*s_w1) -> s_gelu_in (int8)
    rq_ffn2: RequantSite = None  # acc(s_mid*s_w2) -> s_res2 (int32)
    rq_res2in: RequantSite = None  # s_ln1 -> s_res2 (int8 -> int32 path)

    softmax: SoftmaxParams = None
    gelu: GeluParams = None
    ln1: LayerNormParams = None
    ln2: LayerNormParams = None

    def to_json(self):
        out = {}
        for k, v in self.__dict__.items():
            out[k] = v.to_json() if hasattr(v, "to_json") else v
        return out

    @classmethod
    def from_json(cls, d):
        kw = dict(d)
        for k in list(kw):
            if k.startswith("rq_"):
                kw[k] = RequantSite.from_json(kw[k])
        kw["softmax"] = SoftmaxParams.from_json(kw["softmax"])
        kw["gelu"] = GeluParams.from_json(kw["gelu"])
        kw["ln1"] = LayerNormParams.from_json(kw["ln1"])
        kw["ln2"] = LayerNormParams.from_json(kw["ln2"])
        return cls(**kw)


# ---------------------------------------------------------------------------
# Synthetic weights + float calibration
# ---------------------------------------------------------------------------


def _symmetric_scale(x: np.ndarray) -> float:
    """Symmetric int8 scale for max-abs calibration."""
    return float(max(np.abs(x).max(), 1e-8)) / 127.0


@dataclass
class EncoderWeights:
    """Float master weights (build time only) + their int8 quantisations."""

    wq: np.ndarray
    wk: np.ndarray
    wv: np.ndarray
    wo: np.ndarray
    w1: np.ndarray
    w2: np.ndarray
    bq: np.ndarray
    bk: np.ndarray
    bv: np.ndarray
    bo: np.ndarray
    b1: np.ndarray
    b2: np.ndarray
    ln1_gamma: np.ndarray
    ln1_beta: np.ndarray
    ln2_gamma: np.ndarray
    ln2_beta: np.ndarray

    scales: dict = field(default_factory=dict)  # weight scales

    @classmethod
    def generate(cls, seed: int) -> "EncoderWeights":
        rng = np.random.default_rng(seed)

        def w(shape, std):
            return rng.normal(0.0, std, size=shape).astype(np.float64)

        std = 1.0 / math.sqrt(HIDDEN)
        # Q/K projections get a larger std so attention scores reach the
        # +-4-ish range real BERT checkpoints produce: peaked softmax is what
        # makes int8 probability quantisation viable (uniform attention would
        # round every probability to ~1 count at seq len 128).
        std_qk = 2.0 / math.sqrt(HIDDEN)
        ws = cls(
            wq=w((HIDDEN, HIDDEN), std_qk),
            wk=w((HIDDEN, HIDDEN), std_qk),
            wv=w((HIDDEN, HIDDEN), std),
            wo=w((HIDDEN, HIDDEN), std),
            w1=w((HIDDEN, FFN), std),
            w2=w((FFN, HIDDEN), 1.0 / math.sqrt(FFN)),
            bq=w((HIDDEN,), 0.02),
            bk=w((HIDDEN,), 0.02),
            bv=w((HIDDEN,), 0.02),
            bo=w((HIDDEN,), 0.02),
            b1=w((FFN,), 0.02),
            b2=w((HIDDEN,), 0.02),
            ln1_gamma=1.0 + w((HIDDEN,), 0.05),
            ln1_beta=w((HIDDEN,), 0.05),
            ln2_gamma=1.0 + w((HIDDEN,), 0.05),
            ln2_beta=w((HIDDEN,), 0.05),
        )
        for name in ("wq", "wk", "wv", "wo", "w1", "w2"):
            ws.scales[name] = _symmetric_scale(getattr(ws, name))
        return ws

    def quantised(self, name: str) -> np.ndarray:
        w = getattr(self, name)
        s = self.scales[name]
        return np.clip(np.round(w / s), -127, 127).astype(np.int8)

    def bias_int(self, name: str, acc_scale: float) -> np.ndarray:
        b = getattr(self, name)
        return np.round(b / acc_scale).astype(np.int32)


def _float_softmax(x):
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def _float_gelu(x):
    return x * 0.5 * (1.0 + np.vectorize(math.erf)(x / math.sqrt(2.0)))


def _float_layernorm(x, gamma, beta):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return gamma * (x - mu) / np.sqrt(var + 1e-12) + beta


def float_encoder(x: np.ndarray, w: EncoderWeights) -> dict:
    """Float reference forward used only for calibration (build time)."""
    acts = {"in": x}
    q = x @ w.wq + w.bq
    k = x @ w.wk + w.bk
    v = x @ w.wv + w.bv
    acts.update(q=q, k=k, v=v)
    m = x.shape[0]
    heads_out = np.zeros((m, HIDDEN))
    scores_all = []
    for h in range(HEADS):
        sl = slice(h * HEAD_DIM, (h + 1) * HEAD_DIM)
        s = (q[:, sl] @ k[:, sl].T) / math.sqrt(HEAD_DIM)
        p = _float_softmax(s)
        heads_out[:, sl] = p @ v[:, sl]
        scores_all.append(s)
    acts["scores"] = np.stack(scores_all)
    acts["att"] = heads_out
    proj = heads_out @ w.wo + w.bo
    res = proj + x
    acts["res"] = res
    ln1 = _float_layernorm(res, w.ln1_gamma, w.ln1_beta)
    acts["ln1"] = ln1
    mid = _float_gelu(ln1 @ w.w1 + w.b1)
    acts["gelu_in"] = ln1 @ w.w1 + w.b1
    acts["mid"] = mid
    ffn2 = mid @ w.w2 + w.b2
    res2 = ffn2 + ln1
    acts["res2"] = res2
    out = _float_layernorm(res2, w.ln2_gamma, w.ln2_beta)
    acts["out"] = out
    return acts


def calibrate(w: EncoderWeights, seed: int = 7, calib_len: int = MAX_SEQ) -> EncoderQuant:
    """Pick activation scales from a float calibration batch, derive constants."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, size=(calib_len, HIDDEN))
    acts = float_encoder(x, w)

    s_in = _symmetric_scale(acts["in"])
    s_q = _symmetric_scale(acts["q"])
    s_k = _symmetric_scale(acts["k"])
    s_v = _symmetric_scale(acts["v"])
    s_probs = 1.0 / SOFTMAX_OUT_SCALE
    s_att = _symmetric_scale(acts["att"])
    # residual / layernorm domains stay int32; scale chosen ~1/2^12 of range
    s_res = float(max(np.abs(acts["res"]).max(), 1e-8)) / (2**17)
    s_ln1 = _symmetric_scale(acts["ln1"])
    s_gelu_in = _symmetric_scale(acts["gelu_in"])
    s_mid = _symmetric_scale(acts["mid"])
    s_res2 = float(max(np.abs(acts["res2"]).max(), 1e-8)) / (2**17)
    s_out = _symmetric_scale(acts["out"])

    sc = w.scales
    score_scale = s_q * s_k / 8.0  # fold 1/sqrt(d_k) = 1/8 into the scale

    eq = EncoderQuant(
        s_in=s_in, s_q=s_q, s_k=s_k, s_v=s_v, s_probs=s_probs, s_att=s_att,
        s_res=s_res, s_ln1=s_ln1, s_gelu_in=s_gelu_in, s_mid=s_mid,
        s_res2=s_res2, s_out=s_out,
        rq_q=RequantSite.make(s_in * sc["wq"], s_q),
        rq_k=RequantSite.make(s_in * sc["wk"], s_k),
        rq_v=RequantSite.make(s_in * sc["wv"], s_v),
        rq_att=RequantSite.make(s_probs * s_v, s_att),
        rq_proj=RequantSite.make(s_att * sc["wo"], s_res),
        rq_resin=RequantSite.make(s_in, s_res),
        rq_gelu_in=RequantSite.make(s_ln1 * sc["w1"], s_gelu_in),
        rq_ffn2=RequantSite.make(s_mid * sc["w2"], s_res2),
        rq_res2in=RequantSite.make(s_ln1, s_res2),
        softmax=SoftmaxParams.make(score_scale),
        gelu=GeluParams.make(s_gelu_in, s_mid),
        ln1=LayerNormParams(kg=LN_KG, in_scale=s_res, out_scale=s_ln1),
        ln2=LayerNormParams(kg=LN_KG, in_scale=s_res2, out_scale=s_out),
    )
    return eq


def ln_gamma_beta_int(gamma: np.ndarray, beta: np.ndarray, out_scale: float, kg: int = LN_KG):
    gamma_q = np.round(gamma / out_scale * (1 << kg)).astype(np.int64)
    beta_q = np.round(beta / out_scale * (1 << kg)).astype(np.int64)
    return gamma_q, beta_q


def quantparams_to_json(eq: EncoderQuant) -> str:
    return json.dumps({"encoder": eq.to_json(), "hidden": HIDDEN, "heads": HEADS,
                       "ffn": FFN, "max_seq": MAX_SEQ, "num_encoders": NUM_ENCODERS},
                      indent=1)
