"""Build-time compile package: JAX/Pallas I-BERT, AOT lowering, weights.

Everything in this package runs ONCE at `make artifacts`; nothing here is
imported on the rust request path.

int64 is required: the integer-only I-BERT ops accumulate int8 x int8
matmuls into int32 and requantise through int64 intermediates.
"""

import jax

jax.config.update("jax_enable_x64", True)
