"""L2: the integer-only I-BERT encoder in JAX, calling the L1 kernels.

The encoder is a pure function over integer arrays; quantisation constants
come from quantize.py (already folded to integers).  `use_pallas` selects
between the Pallas Tile/PE matmul kernel (L1) and the plain-jnp reference —
both must produce bit-identical outputs (tested), and the AOT artifact is
lowered from the Pallas path.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import iops
from .iops import I8, I32, I64
from .kernels.matmul_int8 import matmul_int8
from .kernels.ref import matmul_int8_ref
from .quantize import HEADS, EncoderQuant, EncoderWeights


@dataclass
class EncoderParams:
    """Integer parameters of one encoder, as consumed by the forward pass."""

    eq: EncoderQuant
    wq: jnp.ndarray  # int8 [H, H]
    wk: jnp.ndarray
    wv: jnp.ndarray
    wo: jnp.ndarray
    w1: jnp.ndarray  # int8 [H, F]
    w2: jnp.ndarray  # int8 [F, H]
    bq: jnp.ndarray  # int32 [H] at acc scale
    bk: jnp.ndarray
    bv: jnp.ndarray
    bo: jnp.ndarray
    b1: jnp.ndarray  # int32 [F]
    b2: jnp.ndarray  # int32 [H]
    ln1_gamma: jnp.ndarray  # int64 [H] Q{kg}
    ln1_beta: jnp.ndarray
    ln2_gamma: jnp.ndarray
    ln2_beta: jnp.ndarray

    @classmethod
    def from_weights(cls, w: EncoderWeights, eq: EncoderQuant) -> "EncoderParams":
        from .quantize import ln_gamma_beta_int

        g1, b1q = ln_gamma_beta_int(w.ln1_gamma, w.ln1_beta, eq.ln1.out_scale, eq.ln1.kg)
        g2, b2q = ln_gamma_beta_int(w.ln2_gamma, w.ln2_beta, eq.ln2.out_scale, eq.ln2.kg)
        j = jnp.asarray
        return cls(
            eq=eq,
            wq=j(w.quantised("wq")), wk=j(w.quantised("wk")), wv=j(w.quantised("wv")),
            wo=j(w.quantised("wo")), w1=j(w.quantised("w1")), w2=j(w.quantised("w2")),
            bq=j(w.bias_int("bq", eq.rq_q.in_scale)),
            bk=j(w.bias_int("bk", eq.rq_k.in_scale)),
            bv=j(w.bias_int("bv", eq.rq_v.in_scale)),
            bo=j(w.bias_int("bo", eq.rq_proj.in_scale)),
            b1=j(w.bias_int("b1", eq.rq_gelu_in.in_scale)),
            b2=j(w.bias_int("b2", eq.rq_ffn2.in_scale)),
            ln1_gamma=j(g1), ln1_beta=j(b1q), ln2_gamma=j(g2), ln2_beta=j(b2q),
        )

    def weight_arrays(self) -> list[tuple[str, np.ndarray]]:
        """Ordered (name, array) list — the AOT parameter calling convention
        shared with the rust runtime (see runtime/artifacts.rs)."""
        names = ["wq", "wk", "wv", "wo", "w1", "w2", "bq", "bk", "bv", "bo",
                 "b1", "b2", "ln1_gamma", "ln1_beta", "ln2_gamma", "ln2_beta"]
        return [(n, np.asarray(getattr(self, n))) for n in names]


def encoder_fwd(p: EncoderParams, x_i8, valid_mask, *, use_pallas: bool = True,
                collect_stages: bool = False):
    """One encoder layer forward: int8 [M, H] -> int8 [M, H].

    valid_mask: bool [M] marking real (non-padded) rows; only attention key
    columns consult it (every other op is row-local), which is what lets a
    fixed-shape artifact agree with the no-padding hardware on short
    sequences.
    """
    mm = matmul_int8 if use_pallas else matmul_int8_ref
    eq = p.eq
    stages = {}

    # ---- Layer 0: Q/K/V linears + Quant (paper Kern_1..3) ----
    q8 = iops.requant8(mm(x_i8, p.wq, p.bq), eq.rq_q)
    k8 = iops.requant8(mm(x_i8, p.wk, p.bk), eq.rq_k)
    v8 = iops.requant8(mm(x_i8, p.wv, p.bv), eq.rq_v)
    stages["q"] = q8
    stages["k"] = k8
    stages["v"] = v8

    qh = iops.head_split(q8, HEADS)  # [A, M, d]
    kh = iops.head_split(k8, HEADS)
    vh = iops.head_split(v8, HEADS)

    # ---- Layer 1: per-head attention dot-product (Kern_4..15) ----
    scores = jax.vmap(lambda a, b: mm(a, b.T))(qh, kh)  # int32 [A, M, M]
    stages["scores"] = scores

    # ---- Layer 2: integer softmax ----
    probs = iops.i_softmax(scores, eq.softmax, valid_mask[None, None, :])
    stages["probs"] = probs

    # ---- Layer 3: softmax matrix-multiply + Quant (Kern_16..27) ----
    att_acc = jax.vmap(lambda a, b: mm(a, b))(probs, vh)  # int32 [A, M, d]
    att8 = iops.requant8(iops.head_merge(att_acc), eq.rq_att)
    stages["att"] = att8

    # ---- Layer 4: output projection + residual + LayerNorm (Kern_28,29) ----
    proj = mm(att8, p.wo, p.bo)
    res = iops.requant32(proj, eq.rq_proj) + iops.requant32(x_i8.astype(I64), eq.rq_resin)
    stages["res"] = res
    ln1 = iops.i_layernorm(res, p.ln1_gamma, p.ln1_beta, eq.ln1)
    stages["ln1"] = ln1

    # ---- Layer 5: FFN (Kern_30,31) + residual + LayerNorm (Kern_32) ----
    g_in = iops.requant8(mm(ln1, p.w1, p.b1), eq.rq_gelu_in)
    stages["gelu_in"] = g_in
    mid = iops.i_gelu(g_in, eq.gelu)
    stages["mid"] = mid
    ffn2 = mm(mid, p.w2, p.b2)
    res2 = iops.requant32(ffn2, eq.rq_ffn2) + iops.requant32(ln1.astype(I64), eq.rq_res2in)
    stages["res2"] = res2
    out = iops.i_layernorm(res2, p.ln2_gamma, p.ln2_beta, eq.ln2)
    stages["out"] = out

    if collect_stages:
        return out, stages
    return out


def model_fwd(p: EncoderParams, x_i8, valid_mask, num_encoders: int, **kw):
    """Full I-BERT: `num_encoders` identical-weight encoders in series.

    The paper builds one physical encoder and estimates 12; we reuse one
    weight set for all 12 (DESIGN.md substitutions).  Output scale equals
    input scale only approximately, so each encoder consumes the previous
    one's int8 output re-interpreted at s_in — acceptable because nothing
    downstream depends on calibrated accuracy, only on bit-exact agreement
    between the three implementations.
    """
    h = x_i8
    for _ in range(num_encoders):
        h = encoder_fwd(p, h, valid_mask, **kw)
    return h
