"""AOT lowering: JAX/Pallas encoder -> HLO *text* artifacts + model FS.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` rust crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import quantize as qz
from . import weights as wexp
from .model import encoder_fwd


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(arr) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(np.asarray(arr).shape, np.asarray(arr).dtype)


def lower_encoder(p, m: int, use_pallas: bool = True) -> tuple[str, list]:
    """Lower one encoder at fixed seq len `m`; weights are runtime params.

    Calling convention (position order, shared with rust runtime/artifacts.rs):
        0: x     int8[m, H]
        1: mask  int32[m]   (0 = padded row / masked key column)
        2..: the 16 arrays of EncoderParams.weight_arrays()
    """
    warrs = [a for _, a in p.weight_arrays()]

    def fn(x, mask, *ws):
        names = [n for n, _ in p.weight_arrays()]
        q = dict(zip(names, ws))
        import dataclasses

        p2 = dataclasses.replace(p, **q)
        return (encoder_fwd(p2, x, mask != 0, use_pallas=use_pallas),)

    x_spec = jax.ShapeDtypeStruct((m, qz.HIDDEN), jnp.int8)
    mask_spec = jax.ShapeDtypeStruct((m,), jnp.int32)
    lowered = jax.jit(fn).lower(x_spec, mask_spec, *[spec_of(a) for a in warrs])
    params = [("x", [m, qz.HIDDEN], "int8"), ("mask", [m], "int32")] + [
        (n, list(np.asarray(a).shape), str(np.asarray(a).dtype))
        for n, a in p.weight_arrays()
    ]
    return to_hlo_text(lowered), params


def lower_smoke() -> str:
    """Tiny artifact for fast runtime unit tests (pallas path included)."""
    from .kernels.matmul_int8 import matmul_int8

    def fn(x, w):
        return (matmul_int8(x, w, bm=2, bn=2),)

    s = jax.ShapeDtypeStruct((2, 2), jnp.int8)
    return to_hlo_text(jax.jit(fn).lower(s, s))


def lower_linear(p, m: int) -> tuple[str, list]:
    """One Linear+Quant module (the paper's Kern_1): for kernel-level PJRT tests."""
    from . import iops
    from .kernels.matmul_int8 import matmul_int8

    def fn(x, w, b):
        return (iops.requant8(matmul_int8(x, w, b), p.eq.rq_q),)

    specs = [
        jax.ShapeDtypeStruct((m, qz.HIDDEN), jnp.int8),
        jax.ShapeDtypeStruct((qz.HIDDEN, qz.HIDDEN), jnp.int8),
        jax.ShapeDtypeStruct((qz.HIDDEN,), jnp.int32),
    ]
    params = [("x", [m, qz.HIDDEN], "int8"), ("w", [qz.HIDDEN, qz.HIDDEN], "int8"),
              ("b", [qz.HIDDEN], "int32")]
    return to_hlo_text(jax.jit(fn).lower(*specs)), params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=wexp.SEED)
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    print("[aot] exporting model file system + goldens ...")
    manifest = wexp.export(out, seed=args.seed)
    _, _, p = wexp.build_params(args.seed)

    print("[aot] lowering encoder (pallas path, m=128) ...")
    hlo, params = lower_encoder(p, qz.MAX_SEQ, use_pallas=True)
    with open(os.path.join(out, "encoder_m128.hlo.txt"), "w") as f:
        f.write(hlo)
    manifest["artifacts"]["encoder_m128"] = {
        "file": "encoder_m128.hlo.txt", "params": [list(t) for t in params],
        "m": qz.MAX_SEQ, "outputs": [["out", [qz.MAX_SEQ, qz.HIDDEN], "int8"]],
    }

    print("[aot] lowering linear module (m=128) ...")
    hlo, params = lower_linear(p, qz.MAX_SEQ)
    with open(os.path.join(out, "linear_m128.hlo.txt"), "w") as f:
        f.write(hlo)
    manifest["artifacts"]["linear_m128"] = {
        "file": "linear_m128.hlo.txt", "params": [list(t) for t in params],
        "m": qz.MAX_SEQ, "outputs": [["out", [qz.MAX_SEQ, qz.HIDDEN], "int8"]],
    }

    print("[aot] lowering smoke artifact ...")
    with open(os.path.join(out, "smoke.hlo.txt"), "w") as f:
        f.write(lower_smoke())
    manifest["artifacts"]["smoke"] = {
        "file": "smoke.hlo.txt",
        "params": [["x", [2, 2], "int8"], ["w", [2, 2], "int8"]],
        "m": 2, "outputs": [["out", [2, 2], "int32"]],
    }

    wexp.write_manifest(out, manifest)
    sizes = {f: os.path.getsize(os.path.join(out, f))
             for f in os.listdir(out) if f.endswith(".hlo.txt")}
    print(f"[aot] wrote artifacts to {out}: {sizes}")


if __name__ == "__main__":
    main()
