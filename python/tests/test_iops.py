"""Integer-op correctness: exact integer semantics + approximation quality."""

import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import iops
from compile import quantize as qz


# ---------------------------------------------------------------------------
# exact integer semantics (the contract rust mirrors)
# ---------------------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(st.integers(-(2**40), 2**40), st.integers(1, 20))
def test_rshift_round_matches_floor_half(x, n):
    got = int(iops.rshift_round(jnp.int64(x), n))
    want = math.floor(x / 2**n + 0.5)
    assert got == want


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**62))
def test_isqrt_exact(n):
    got = int(iops.isqrt(jnp.asarray([n], dtype=jnp.int64))[0])
    assert got == math.isqrt(n)


@settings(max_examples=50, deadline=None)
@given(st.integers(-(2**40), 2**40), st.integers(1, 2**20))
def test_floor_div_is_python_floordiv(a, b):
    assert int(iops.floor_div(jnp.int64(a), jnp.int64(b))) == a // b


def test_requant8_clips():
    site = qz.RequantSite.make(1.0, 1.0 / 1024)  # factor 1024
    out = iops.requant8(jnp.asarray([10**6, -(10**6), 0], dtype=jnp.int64), site)
    assert list(np.asarray(out)) == [127, -127, 0]


def test_dyadic_accuracy():
    for f in [0.001, 0.7, 1.0, 3.14159, 1000.0, 30000.0]:
        m, n = qz.dyadic(f)
        assert 2**14 <= m < 2**15
        assert abs(m / 2**n - f) / f < 2**-14


# ---------------------------------------------------------------------------
# approximation quality of the I-BERT polynomials (vs float reference)
# ---------------------------------------------------------------------------


def test_i_softmax_close_to_float(rng):
    scale = 0.01
    sm = qz.SoftmaxParams.make(scale)
    scores = rng.integers(-400, 400, size=(16, 64)).astype(np.int32)
    got = np.asarray(iops.i_softmax(jnp.asarray(scores), sm)).astype(np.float64) / 127.0
    x = scores.astype(np.float64) * scale
    e = np.exp(x - x.max(-1, keepdims=True))
    want = e / e.sum(-1, keepdims=True)
    assert np.abs(got - want).max() < 0.03
    # rows sum to ~1
    assert np.abs(got.sum(-1) - 1.0).max() < 0.1


def test_i_softmax_mask_zeroes_padded_columns(rng):
    sm = qz.SoftmaxParams.make(0.01)
    scores = rng.integers(-400, 400, size=(4, 8)).astype(np.int32)
    mask = np.array([True] * 5 + [False] * 3)
    got = np.asarray(iops.i_softmax(jnp.asarray(scores), sm, jnp.asarray(mask)[None, :]))
    assert (got[:, 5:] == 0).all()
    # masked result equals the dense result on the valid prefix
    dense = np.asarray(iops.i_softmax(jnp.asarray(scores[:, :5]), sm))
    np.testing.assert_array_equal(got[:, :5], dense)


def test_i_gelu_close_to_float():
    scale = 0.05
    gp = qz.GeluParams.make(scale, 0.05)
    q = np.arange(-127, 128, dtype=np.int8)
    got = np.asarray(iops.i_gelu(jnp.asarray(q), gp)).astype(np.float64) * gp.out.out_scale
    x = q.astype(np.float64) * scale
    want = x * 0.5 * (1 + np.vectorize(math.erf)(x / math.sqrt(2)))
    # I-BERT's own polynomial has ~1e-1 worst-case absolute error on gelu
    assert np.abs(got - want).max() < 0.15
    # and must be close in L2
    assert np.sqrt(((got - want) ** 2).mean()) < 0.05


def test_i_layernorm_close_to_float(rng):
    h = 768
    # out_scale must cover the normalised range (~±4.5) or clip8 saturates
    ln = qz.LayerNormParams(kg=qz.LN_KG, in_scale=1e-4, out_scale=0.04)
    xf = rng.normal(0, 1.0, size=(4, h))
    q = np.round(xf / ln.in_scale).astype(np.int64)
    gamma = 1.0 + rng.normal(0, 0.05, h)
    beta = rng.normal(0, 0.05, h)
    gq, bq = qz.ln_gamma_beta_int(gamma, beta, ln.out_scale, ln.kg)
    got = np.asarray(iops.i_layernorm(jnp.asarray(q), jnp.asarray(gq), jnp.asarray(bq), ln))
    got = got.astype(np.float64) * ln.out_scale
    mu = xf.mean(-1, keepdims=True)
    sd = xf.std(-1, keepdims=True)
    want = gamma * (xf - mu) / sd + beta
    assert np.abs(got - want).max() < 0.08


def test_i_layernorm_row_local(rng):
    """LayerNorm of a stacked batch equals per-row LayerNorm (row-locality —
    the property that makes the no-padding hardware design sound)."""
    ln = qz.LayerNormParams(kg=qz.LN_KG, in_scale=1e-4, out_scale=0.02)
    q = rng.integers(-(2**17), 2**17, size=(6, 768)).astype(np.int64)
    gq = np.full(768, 1 << qz.LN_KG, dtype=np.int64)
    bq = np.zeros(768, dtype=np.int64)
    full = np.asarray(iops.i_layernorm(jnp.asarray(q), jnp.asarray(gq), jnp.asarray(bq), ln))
    for i in range(6):
        row = np.asarray(iops.i_layernorm(jnp.asarray(q[i : i + 1]), jnp.asarray(gq),
                                          jnp.asarray(bq), ln))
        np.testing.assert_array_equal(full[i : i + 1], row)
