"""L1 Pallas kernel vs pure-jnp oracle: hypothesis sweeps shapes + blocks."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.matmul_int8 import matmul_int8, mxu_utilization, vmem_bytes
from compile.kernels.ref import matmul_int8_ref


def _mm_case(rng, m, k, n, bm, bn, with_bias):
    x = rng.integers(-127, 128, size=(m, k), dtype=np.int8)
    w = rng.integers(-127, 128, size=(k, n), dtype=np.int8)
    b = rng.integers(-(2**20), 2**20, size=(n,), dtype=np.int32) if with_bias else None
    got = np.asarray(matmul_int8(jnp.asarray(x), jnp.asarray(w),
                                 None if b is None else jnp.asarray(b), bm=bm, bn=bn))
    want = np.asarray(matmul_int8_ref(jnp.asarray(x), jnp.asarray(w),
                                      None if b is None else jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 96),
    n=st.integers(1, 80),
    bm=st.sampled_from([2, 8, 16, 32]),
    bn=st.sampled_from([4, 16, 64, 128]),
    with_bias=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_pallas_matches_ref_hypothesis(m, k, n, bm, bn, with_bias, seed):
    rng = np.random.default_rng(seed)
    _mm_case(rng, m, k, n, bm, bn, with_bias)


def test_matmul_encoder_shapes(rng):
    """The exact shapes the encoder uses (paper modules)."""
    for m, k, n in [(128, 768, 768), (128, 768, 3072), (128, 3072, 768),
                    (128, 64, 128), (128, 128, 64), (1, 768, 768), (38, 768, 768)]:
        _mm_case(rng, m, k, n, 32, 128, True)


def test_matmul_extreme_values(rng):
    """Saturated int8 inputs cannot overflow the int32 accumulator."""
    m, k, n = 8, 3072, 16
    x = np.full((m, k), 127, dtype=np.int8)
    w = np.full((k, n), -127, dtype=np.int8)
    got = np.asarray(matmul_int8(jnp.asarray(x), jnp.asarray(w)))
    assert (got == 3072 * 127 * -127).all()
    assert got.dtype == np.int32


def test_vmem_budget():
    """Every block config used by the encoder fits VMEM (16 MB)."""
    for bm, bn, k in [(32, 128, 768), (32, 128, 3072), (128, 128, 64), (64, 64, 128)]:
        assert vmem_bytes(bm, bn, k) < 16 * 2**20


def test_mxu_estimates_monotone():
    assert mxu_utilization(128, 128, 768) == 1.0
    assert mxu_utilization(32, 128, 768) < 1.0
    assert 0 < mxu_utilization(1, 1, 1) < 0.01
