import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile import quantize as qz  # noqa: E402  (enables x64)
from compile.model import EncoderParams  # noqa: E402


@pytest.fixture(scope="session")
def params():
    w = qz.EncoderWeights.generate(12345)
    eq = qz.calibrate(w)
    return w, eq, EncoderParams.from_weights(w, eq)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(99)
