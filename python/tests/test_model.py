"""Encoder-level properties: pallas==ref bit-exactness, no-padding
equivalence (the paper's §7.1 design claim), and golden stability."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantize as qz
from compile.model import encoder_fwd, model_fwd
from compile.weights import golden_input


def test_pallas_matches_ref_bitexact(params):
    _, eq, p = params
    x = golden_input(128, eq, seed=5)
    mask = jnp.ones(128, bool)
    a = np.asarray(encoder_fwd(p, jnp.asarray(x), mask, use_pallas=True))
    b = np.asarray(encoder_fwd(p, jnp.asarray(x), mask, use_pallas=False))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=6, deadline=None)
@given(m=st.sampled_from([1, 3, 17, 38, 54, 127]))
def test_no_padding_equivalence(params, m):
    """encoder(x[:m]) == encoder(pad(x), mask)[:m] — a fixed-shape artifact
    reproduces the no-padding hardware results for short sequences."""
    _, eq, p = params
    x = golden_input(128, eq, seed=6)
    mask = np.zeros(128, bool)
    mask[:m] = True
    padded = np.asarray(encoder_fwd(p, jnp.asarray(x), jnp.asarray(mask),
                                    use_pallas=False))
    dense = np.asarray(encoder_fwd(p, jnp.asarray(x[:m]), jnp.ones(m, bool),
                                   use_pallas=False))
    np.testing.assert_array_equal(padded[:m], dense)


def test_model12_runs(params):
    _, eq, p = params
    x = golden_input(16, eq, seed=7)
    out = np.asarray(model_fwd(p, jnp.asarray(x), jnp.ones(16, bool), 3,
                               use_pallas=False))
    assert out.shape == (16, qz.HIDDEN)
    assert out.dtype == np.int8
    assert np.abs(out).max() > 0  # not degenerate


def test_encoder_deterministic(params):
    _, eq, p = params
    x = golden_input(8, eq, seed=8)
    a = np.asarray(encoder_fwd(p, jnp.asarray(x), jnp.ones(8, bool), use_pallas=False))
    b = np.asarray(encoder_fwd(p, jnp.asarray(x), jnp.ones(8, bool), use_pallas=False))
    np.testing.assert_array_equal(a, b)


def test_quantparams_json_roundtrip(params):
    _, eq, _ = params
    import json

    j = json.loads(qz.quantparams_to_json(eq))
    eq2 = qz.EncoderQuant.from_json(j["encoder"])
    assert eq2.rq_q.m == eq.rq_q.m
    assert eq2.softmax.q_ln2 == eq.softmax.q_ln2
    assert eq2.gelu.q_b == eq.gelu.q_b
    assert eq2.ln1.kg == eq.ln1.kg
