"""GTF1 round-trip (the rust twin is tested in rust/src/util/tensorfile.rs,
and rust integration tests read the files this side writes)."""

import numpy as np
import pytest

from compile.tensorfile import read_tensor, write_tensor


@pytest.mark.parametrize("dtype", [np.int8, np.int32, np.int64, np.float32])
@pytest.mark.parametrize("shape", [(3,), (2, 5), (4, 3, 2), ()])
def test_roundtrip(tmp_path, dtype, shape, rng):
    if dtype == np.float32:
        arr = rng.normal(size=shape).astype(dtype)
    else:
        arr = rng.integers(-100, 100, size=shape).astype(dtype)
    p = str(tmp_path / "t.bin")
    write_tensor(p, arr)
    back = read_tensor(p)
    assert back.dtype == arr.dtype
    assert back.shape == arr.shape
    np.testing.assert_array_equal(back, arr)


def test_bad_magic(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"XXXX1234")
    with pytest.raises(ValueError):
        read_tensor(str(p))
