#!/usr/bin/env python3
"""Validate telemetry artifacts emitted by `--trace-out` / `--metrics-out`.

Chrome trace-event JSON (the Perfetto / chrome://tracing input format):
  * top level: object with a `traceEvents` array (JSON Object Format)
  * every event: `ph` phase string, `pid`, `ts` (non-negative number,
    microseconds), `name` (except where optional)
  * async begin/end pairs (`b`/`e`) balance per (cat, id) with begin
    timestamps <= end timestamps
  * instants carry a scope `s` in {g, p, t}

Metrics JSONL (obs_metrics/v1): one JSON object per line, first line a
header with `schema: obs_metrics/v1`, every line a `type` tag.

Stdlib only; exits non-zero with a message on the first violation.
"""

import argparse
import json
import sys

KNOWN_PHASES = {"B", "E", "X", "i", "I", "C", "b", "n", "e", "s", "t", "f", "M"}
METRIC_TYPES = {"header", "bucket", "kernel", "fifo", "link", "summary"}


def fail(msg: str) -> None:
    sys.exit(f"schema check failed: {msg}")


def check_trace(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        fail(f"{path}: top level must be an object (JSON Object Format)")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: missing or empty traceEvents array")

    open_async = {}  # (cat, id) -> begin ts stack
    for n, ev in enumerate(events):
        where = f"{path}: traceEvents[{n}]"
        if not isinstance(ev, dict):
            fail(f"{where}: event is not an object")
        ph = ev.get("ph")
        if ph not in KNOWN_PHASES:
            fail(f"{where}: unknown phase {ph!r}")
        if "pid" not in ev:
            fail(f"{where}: missing pid")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                fail(f"{where}: bad ts {ts!r}")
        if ph in ("b", "e"):
            for req in ("cat", "id", "name"):
                if req not in ev:
                    fail(f"{where}: async event missing {req!r}")
            key = (ev["cat"], ev["id"])
            if ph == "b":
                open_async.setdefault(key, []).append(ev["ts"])
            else:
                stack = open_async.get(key)
                if not stack:
                    fail(f"{where}: async end without begin for {key}")
                if ev["ts"] < stack[-1]:
                    fail(f"{where}: async span {key} ends before it begins")
                stack.pop()
        elif ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                fail(f"{where}: complete event missing dur")
        elif ph == "i":
            if ev.get("s") not in ("g", "p", "t"):
                fail(f"{where}: instant scope must be g/p/t, got {ev.get('s')!r}")
        elif ph == "M":
            if "args" not in ev:
                fail(f"{where}: metadata event missing args")
    dangling = {k: v for k, v in open_async.items() if v}
    if dangling:
        fail(f"{path}: unterminated async spans: {sorted(dangling)[:5]}")
    return len(events)


def check_metrics(path: str) -> int:
    lines = 0
    with open(path) as f:
        for n, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            where = f"{path}:{n + 1}"
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"{where}: unparseable line: {e}")
            if not isinstance(obj, dict):
                fail(f"{where}: line is not an object")
            t = obj.get("type")
            if t not in METRIC_TYPES:
                fail(f"{where}: unknown line type {t!r}")
            if lines == 0:
                if t != "header" or obj.get("schema") != "obs_metrics/v1":
                    fail(f"{where}: first line must be an obs_metrics/v1 header")
            lines += 1
    if lines == 0:
        fail(f"{path}: empty metrics stream")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON from --trace-out")
    ap.add_argument("--metrics", help="obs_metrics/v1 JSONL from --metrics-out")
    args = ap.parse_args()
    n = check_trace(args.trace)
    print(f"{args.trace}: OK ({n} trace events)")
    if args.metrics:
        m = check_metrics(args.metrics)
        print(f"{args.metrics}: OK ({m} metric lines)")


if __name__ == "__main__":
    main()
