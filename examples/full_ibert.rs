//! End-to-end driver: the full 72-FPGA, 12-encoder I-BERT of Fig. 17.
//!
//!   make artifacts && cargo run --release --example full_ibert
//!
//! Simulates all 12 encoder clusters (six FPGAs each) chained across 12
//! serially-connected 100G switches, runs real GLUE-length inferences in
//! functional mode (bit-exact against the reference), and reports the
//! measured full-model latency against the paper's Table 2 estimates and
//! a latency distribution over the GLUE length mix.

use std::sync::Arc;

use galapagos_llm::cycles_to_us;
use galapagos_llm::eval::latency_model::{estimate_model_latency_us, PAPER_TABLE2_MS};
use galapagos_llm::eval::tables::measure_components;
use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
use galapagos_llm::eval::workload::GlueWorkload;
use galapagos_llm::ibert::encoder::{model_forward, rows_i8};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::weights::{load_golden, ModelParams};
use galapagos_llm::util::table::{f2, f3, Table};

fn main() -> anyhow::Result<()> {
    let dir = ModelParams::default_dir();
    let params = Arc::new(ModelParams::load(&dir)?);

    // ---- functional 12-encoder chain at the GLUE average length ----
    let m = 38;
    let x = rows_i8(load_golden(&dir, "input_m128")?.as_i8()?)[..m].to_vec();
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(params.clone()));
    cfg.encoders = 12;
    cfg.inferences = 2;
    cfg.input = Some(Arc::new(x.clone()));
    println!("building 72-FPGA / 12-switch platform (12 encoder clusters + eval FPGA) ...");
    let mut tb = build_testbed(&cfg)?;
    println!(
        "platform: {} kernels across {} FPGAs",
        tb.sim.kernel_count(),
        tb.spec.switch_of.len()
    );
    tb.sim.start();
    tb.sim.run()?;
    let (x_c, t_c, _i) = tb.sim.trace.xti(tb.sink_id).unwrap();
    let got = tb.sink.lock().unwrap().matrix(0).expect("incomplete model output");
    let want = model_forward(&params, &x, 12);
    assert_eq!(got, want, "72-FPGA simulation != 12-encoder reference");
    println!("12-encoder output bit-exact vs reference ... OK");
    println!(
        "full-model latency at m={m}: {:.3} ms measured in-sim (first output {:.3} ms)",
        cycles_to_us(t_c) / 1e3,
        cycles_to_us(x_c) / 1e3
    );
    println!(
        "events processed: {}  packets: {}",
        tb.sim.trace.events_processed, tb.sim.fabric.stats.packets
    );

    // ---- Table 2 regenerated: measured chain vs Eq. 1 vs paper ----
    let mut t = Table::new(
        "\nfull I-BERT latency (ms): direct 72-FPGA sim vs Eq. 1 vs paper",
        &["seq len", "sim chain", "Eq.1 (d=1.1us)", "paper"],
    );
    for &m in &[8usize, 32, 128] {
        let mut c2 = TestbedConfig::proof_of_concept(m, Mode::Timing);
        c2.encoders = 12;
        let mut tb2 = build_testbed(&c2)?;
        tb2.sim.start();
        tb2.sim.run()?;
        let (_, t_chain, _) = tb2.sim.trace.xti(tb2.sink_id).unwrap();
        let comp = measure_components(m)?;
        let eq1 = estimate_model_latency_us(comp, 12, 1.1) / 1e3;
        let paper = PAPER_TABLE2_MS.iter().find(|(l, _)| *l == m).unwrap().1;
        t.row(vec![m.to_string(), f3(cycles_to_us(t_chain) / 1e3), f3(eq1), f3(paper)]);
    }
    println!("{}", t.render());

    // ---- latency over the GLUE length distribution ----
    let mut w = GlueWorkload::glue(7);
    let lens = w.sample_n(24);
    let mut lat: Vec<f64> = Vec::new();
    let mut cache: std::collections::HashMap<usize, f64> = Default::default();
    for &l in &lens {
        let ms = *cache.entry(l).or_insert_with(|| {
            let c = measure_components(l).unwrap();
            estimate_model_latency_us(c, 12, 1.1) / 1e3
        });
        lat.push(ms);
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = lat.iter().sum::<f64>() / lat.len() as f64;
    println!(
        "GLUE length mix (n={}, mean len {:.1}): mean {} ms  p50 {} ms  p95 {} ms  (paper: 2.58 ms)",
        lens.len(),
        lens.iter().sum::<usize>() as f64 / lens.len() as f64,
        f2(mean),
        f2(lat[lat.len() / 2]),
        f2(lat[(lat.len() * 95) / 100]),
    );
    Ok(())
}
