//! GMI demo (§5): collectives within and across Galapagos clusters —
//! Broadcast, Scatter, Gather, Reduce, and the Allgather composition, all
//! running on the simulated fabric with gateway-mediated inter-cluster
//! messaging (one-byte GMI headers).
//!
//!   cargo run --release --example gmi_collectives

use std::collections::HashMap;

use galapagos_llm::cycles_to_us;
use galapagos_llm::gmi::gateway::{Gateway, GatewayConfig};
use galapagos_llm::gmi::{Communicator, GmiKernel, GmiOp, Out, ReduceFn, ScatterPolicy};
use galapagos_llm::sim::engine::{KernelBehavior, KernelIo, START_TAG};
use galapagos_llm::sim::fabric::{FpgaId, SwitchId};
use galapagos_llm::sim::fifo::Fifo;
use galapagos_llm::sim::packet::{GlobalKernelId, MsgMeta, Packet, Payload};
use galapagos_llm::sim::Sim;

fn k(c: u8, n: u8) -> GlobalKernelId {
    GlobalKernelId::new(c, n)
}

struct Tx {
    dst: GlobalKernelId,
    rows: Vec<Vec<i32>>,
    stream: u8,
}
impl KernelBehavior for Tx {
    fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            let n = self.rows.len() as u32;
            for (i, r) in self.rows.iter().enumerate() {
                io.send(
                    self.dst,
                    MsgMeta { stream: self.stream, row: i as u32, rows: n, inference: 0 },
                    Payload::row_i32(r.clone()),
                );
            }
        }
    }
}

struct Rx {
    label: &'static str,
}
impl KernelBehavior for Rx {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
        if let Payload::RowI32(v) = &pkt.payload {
            println!(
                "  t={:>7} cyc ({:>6.2} us)  {} {} got row {} = {:?}",
                io.now,
                cycles_to_us(io.now),
                self.label,
                io.self_id,
                pkt.meta.row,
                v
            );
        }
    }
    fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
}

fn main() -> anyhow::Result<()> {
    // communicator bookkeeping (§5.1): an inter-communicator across two
    // clusters with a subgroup used for the reduce
    let comm = Communicator::new(1, vec![k(0, 1), k(0, 2), k(1, 5), k(1, 6)])?;
    println!(
        "communicator {}: {} members, intra={}, rank of c1k5 = {:?}",
        comm.id,
        comm.size(),
        comm.is_intra(),
        comm.rank_of(k(1, 5))
    );
    let sub = comm.subgroup(2, &[0, 1])?;
    println!("subgroup {}: members {:?}\n", sub.id, sub.members);

    let mut sim = Sim::new();
    for f in 0..4 {
        sim.fabric.attach(FpgaId(f), SwitchId(f / 2)); // two switches, d between
    }

    // cluster 0: producer + scatter + reduce
    sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
        dst: k(0, 2),
        rows: (0..4).map(|i| vec![i, 10 * i]).collect(),
        stream: 0,
    }))?;
    // scatter rows round-robin to one local kernel and one REMOTE kernel
    // (the remote leg exercises the gateway + 1-byte GMI header path)
    sim.add_kernel(
        k(0, 2),
        FpgaId(0),
        Fifo::new(1 << 16),
        Box::new(GmiKernel::new(GmiOp::Scatter {
            dsts: vec![Out::tagged(k(0, 3), 0), Out::tagged(k(1, 5), 0)],
            policy: ScatterPolicy::RoundRobin,
        })),
    )?;
    sim.add_kernel(k(0, 3), FpgaId(1), Fifo::new(1 << 16), Box::new(Rx { label: "[scatter-local]" }))?;

    // cluster 1: gateway with a virtual Broadcast module at id 0
    let mut virtuals = HashMap::new();
    virtuals.insert(0u8, GmiOp::Broadcast { dsts: vec![Out::to(k(1, 6)), Out::to(k(1, 7))] });
    sim.add_kernel(
        k(1, 0),
        FpgaId(2),
        Fifo::new(1 << 16),
        Box::new(Gateway::new(GatewayConfig { cluster: 1, virtuals })),
    )?;
    sim.add_kernel(k(1, 5), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx { label: "[scatter-remote]" }))?;
    sim.add_kernel(k(1, 6), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx { label: "[vbcast]" }))?;
    sim.add_kernel(k(1, 7), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx { label: "[vbcast]" }))?;

    // a second producer sends THROUGH the gateway's virtual broadcast
    sim.add_kernel(k(0, 4), FpgaId(1), Fifo::new(1 << 16), Box::new(Tx {
        dst: k(1, 0), // the gateway itself => virtual module 0
        rows: vec![vec![777]],
        stream: 0,
    }))?;

    println!("running: scatter (intra+inter cluster) and gateway virtual broadcast");
    sim.start();
    sim.run()?;
    println!(
        "\nfabric: {} packets / {} flits; inter-FPGA {}; inter-switch {} (each +1.1 us)",
        sim.fabric.stats.packets,
        sim.fabric.stats.flits,
        sim.fabric.stats.inter_fpga_packets,
        sim.fabric.stats.inter_switch_packets
    );

    // reduce demo: two ranks sum into one stream
    println!("\nreduce (Sum) of two ranked streams:");
    let mut sim2 = Sim::new();
    for f in 0..2 {
        sim2.fabric.attach(FpgaId(f), SwitchId(0));
    }
    for (kid, stream, base) in [(1u8, 0u8, 0i32), (2, 1, 100)] {
        sim2.add_kernel(k(0, kid), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![base + 1, base + 2]],
            stream,
        }))?;
    }
    sim2.add_kernel(
        k(0, 3),
        FpgaId(0),
        Fifo::new(1 << 16),
        Box::new(GmiKernel::new(GmiOp::Reduce {
            n_srcs: 2,
            dst: Out::to(k(0, 4)),
            f: ReduceFn::Sum,
        })),
    )?;
    sim2.add_kernel(k(0, 4), FpgaId(1), Fifo::new(1 << 16), Box::new(Rx { label: "[reduce]" }))?;
    sim2.start();
    sim2.run()?;
    Ok(())
}
