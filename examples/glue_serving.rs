//! Serving example: the PJRT request path (no Python, no simulator).
//!
//!   make artifacts && cargo run --release --example glue_serving
//!
//! Loads the AOT-compiled encoder artifact, then serves a stream of
//! GLUE-length requests through the 12-encoder model, reporting latency
//! percentiles and throughput — the "low-latency batch-1 serving" story
//! the paper argues FPGAs are good at, on our CPU-PJRT stand-in.

use std::time::Instant;

use galapagos_llm::eval::workload::GlueWorkload;
use galapagos_llm::ibert::encoder::rows_i8;
use galapagos_llm::ibert::weights::{load_golden, ModelParams};
use galapagos_llm::runtime::{EncoderEngine, PjrtRuntime};
use galapagos_llm::util::rng::Rng;
use galapagos_llm::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    let dir = ModelParams::default_dir();
    let rt = PjrtRuntime::cpu()?;
    let t0 = Instant::now();
    let engine = EncoderEngine::load(&rt, &dir)?;
    println!(
        "compiled encoder artifact on {} in {:.2} s (one-time)",
        rt.platform(),
        t0.elapsed().as_secs_f64()
    );

    let base = rows_i8(load_golden(&dir, "input_m128")?.as_i8()?);
    let mut wl = GlueWorkload::glue(11);
    let mut rng = Rng::new(5);
    let n_requests = 24;
    let encoders = 4; // depth kept modest so the demo stays snappy on CPU

    let mut lat_ms: Vec<f64> = Vec::new();
    let run_t0 = Instant::now();
    for i in 0..n_requests {
        let m = wl.sample();
        // perturb the input a little per request
        let mut x = base[..m].to_vec();
        let r = rng.range_usize(0, m - 1);
        let c = rng.range_usize(0, x[0].len() - 1);
        x[r][c] = x[r][c].wrapping_add(1);
        let t = Instant::now();
        let out = engine.infer_model(&x, encoders)?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        lat_ms.push(ms);
        assert_eq!(out.len(), m);
        if i < 3 {
            println!("request {i}: len {m:>3} -> {:.1} ms", ms);
        }
    }
    let wall = run_t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut t = Table::new(
        format!("\nserved {n_requests} GLUE-length requests through {encoders} encoders (CPU PJRT)").leak(),
        &["metric", "value"],
    );
    t.row(vec!["p50 latency (ms)".into(), f2(lat_ms[lat_ms.len() / 2])]);
    t.row(vec!["p95 latency (ms)".into(), f2(lat_ms[(lat_ms.len() * 95) / 100])]);
    t.row(vec!["max latency (ms)".into(), f2(*lat_ms.last().unwrap())]);
    t.row(vec!["throughput (req/s)".into(), f2(n_requests as f64 / wall)]);
    println!("{}", t.render());
    println!(
        "note: absolute numbers are CPU-PJRT, not FPGA; the FPGA latency model \
         lives in the simulator (see `cargo bench` tables)"
    );
    Ok(())
}
