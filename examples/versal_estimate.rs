//! §9 reproduction: estimate I-BERT on AMD Versal ACAP devices, with an
//! ablation over the estimator's assumptions (the paper's engineers hinted
//! at "another factor of 2" from better data placement — we sweep it).
//!
//!   cargo run --release --example versal_estimate

use galapagos_llm::baselines::A100;
use galapagos_llm::eval::tables::versal_table;
use galapagos_llm::versal::aie::AieArray;
use galapagos_llm::versal::estimate::{
    estimate_encoder, reconfig_device_estimate, VersalAssumptions,
};
use galapagos_llm::versal::mapping::versal_encoder_mapping;
use galapagos_llm::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    println!("{}", versal_table()?.render());

    let a = AieArray::vck190();
    println!("VCK190: {} AIEs, {:.1} peak INT8 TOPS (plain-MAC model; datasheet 133 with ML packing)",
             a.total_aies(), a.peak_int8_tops());

    // per-kernel breakdown (Fig. 23 mapping)
    let mut t = Table::new("per-kernel mapping (one encoder on one VCK190)",
                           &["kernel", "AIEs", "latency (us)"]);
    for k in versal_encoder_mapping(128, 768, 3072) {
        t.row(vec![k.name.into(), k.aies.to_string(), f2(k.latency_us(&a))]);
    }
    println!("{}", t.render());

    // ablation: the AMD engineer's "another factor of 2" + AIE-ML packing
    let mut t = Table::new(
        "ablation: estimate sensitivity (full model, us)",
        &["variant", "model latency (us)", "vs A100 (770 us)"],
    );
    for (name, macs, nl) in [
        ("paper assumptions (64 MAC/cycle)", 64u64, 26.1),
        ("better data placement (x2 -> 128 MAC/cycle)", 128, 26.1),
        ("AIE-ML (256 int8 MAC/cycle)", 256, 26.1),
        ("paper MACs, nonlinear fully hidden", 64, 0.0),
    ] {
        let mut arr = a;
        arr.int8_macs_per_cycle = macs;
        let asm = VersalAssumptions { nonlinear_overhead_us: nl, ..Default::default() };
        let e = estimate_encoder(&arr, 128, 768, 3072, &asm)?;
        t.row(vec![
            name.into(),
            f2(e.model_us),
            f2(e.model_us / (A100.batch1_latency_ms * 1e3)),
        ]);
    }
    println!("{}", t.render());

    // §9.3's single-card argument: weight reconfiguration ping-pong
    let weights = 4 * 768 * 768 + 2 * 768 * 3072;
    let (devices, reconfig_us, compute_us) = reconfig_device_estimate(&a, weights, 124.1);
    println!(
        "weight-reconfiguration scheme: one encoder's weights ({:.2} MB) load in {:.0} us \
         from DRAM vs {:.1} us compute => {} devices suffice with ping-pong \
         (paper argues 2 with cross-pipeline overlap)",
        weights as f64 / 1e6, reconfig_us, compute_us, devices
    );
    Ok(())
}
