//! Quickstart: simulate the paper's six-FPGA I-BERT encoder end to end.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Builds the 38-kernel encoder cluster (Fig. 14), streams one GLUE-length
//! inference through the simulated FPGAs in functional mode, verifies the
//! output is bit-exact against (a) the native rust reference and (b) the
//! AOT-compiled JAX/Pallas artifact executed via PJRT, and prints the
//! measured latency components.

use std::sync::Arc;

use galapagos_llm::cycles_to_us;
use galapagos_llm::eval::testbed::{run_encoder_once, TestbedConfig};
use galapagos_llm::ibert::encoder::{encoder_forward, rows_i8};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::weights::{load_golden, ModelParams};
use galapagos_llm::runtime::{EncoderEngine, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    let dir = ModelParams::default_dir();
    let params = Arc::new(ModelParams::load(&dir)?);
    println!("loaded model file system: {} weight bytes on-chip", params.weight_bytes());

    // one GLUE-average-length inference (38 tokens, no padding)
    let m = 38;
    let x = rows_i8(load_golden(&dir, "input_m128")?.as_i8()?)[..m].to_vec();

    // --- simulate the six-FPGA cluster, functional mode ---
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(params.clone()));
    cfg.input = Some(Arc::new(x.clone()));
    let run = run_encoder_once(&cfg)?;
    let tb = &run.testbed;
    let sim_out = tb.sink.lock().unwrap().matrix(0).expect("incomplete output");
    println!(
        "six-FPGA simulation: X={} T={} I={} cycles  ({:.1} us first output, {:.1} us total)",
        run.x, run.t, run.i,
        cycles_to_us(run.x), cycles_to_us(run.t)
    );
    println!(
        "fabric: {} packets, {} flits, {} inter-FPGA",
        tb.sim.fabric.stats.packets, tb.sim.fabric.stats.flits, tb.sim.fabric.stats.inter_fpga_packets
    );

    // --- cross-check 1: native rust reference ---
    let native = encoder_forward(&params, &x).out;
    assert_eq!(sim_out, native, "simulation != native reference");
    println!("bit-exact vs native rust reference  ... OK");

    // --- cross-check 2: the AOT JAX/Pallas artifact via PJRT ---
    let rt = PjrtRuntime::cpu()?;
    let engine = EncoderEngine::load(&rt, &dir)?;
    let pjrt_out = engine.infer(&x)?;
    assert_eq!(sim_out, pjrt_out, "simulation != PJRT artifact");
    println!("bit-exact vs PJRT-executed Pallas artifact ... OK");

    println!("\nall three implementations agree; encoder latency {:.2} us at m={m}",
             cycles_to_us(run.t));
    Ok(())
}
