use galapagos_llm::eval::tables;
fn main() -> anyhow::Result<()> {
    println!("{}", tables::table1()?.render());
    println!("{}", tables::table2()?.render());
    println!("{}", tables::table3()?.render());
    println!("{}", tables::table4()?.render());
    println!("{}", tables::table5()?.render());
    println!("{}", tables::fig15()?.render());
    println!("{}", tables::fig16(&[1, 8, 32, 128])?.render());
    println!("{}", tables::fig20(&[1, 8, 32, 128])?.render());
    println!("{}", tables::versal_table()?.render());
    println!("{}", tables::scaling_table()?.render());
    Ok(())
}
