//! Engine diagnostic: discrete-event throughput of the simulator itself
//! (the §Perf L3 metric), separating testbed-build cost from run cost.
//!
//!   cargo run --release --example engine_throughput
use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::quick();
    b.bench("testbed build only (m=128)", || {
        black_box(build_testbed(&TestbedConfig::proof_of_concept(128, Mode::Timing)).unwrap());
    });
    // run-only throughput: amortize one build over 8 pipelined inferences,
    // in both engine configurations
    for reference in [true, false] {
        let mut cfg = TestbedConfig::proof_of_concept(128, Mode::Timing);
        cfg.inferences = 8;
        let mut tb = build_testbed(&cfg).unwrap();
        if reference {
            tb.sim.reference_mode();
        }
        tb.sim.start();
        let t0 = std::time::Instant::now();
        tb.sim.run().unwrap();
        let dt = t0.elapsed();
        println!(
            "run-only [{}]: {} events in {:.1} ms -> {:.2} M events/s",
            if reference { "reference" } else { "coalesced" },
            tb.sim.trace.events_processed,
            dt.as_secs_f64() * 1e3,
            tb.sim.trace.events_processed as f64 / dt.as_secs_f64() / 1e6
        );
    }
}
