//! E8: regenerate Fig. 20 (per-layer throughput vs sequence length).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("fig20: per-layer throughput sweep", || {
        tables::fig20(&[1, 2, 4, 8, 16, 32, 64, 128]).unwrap()
    });
    println!("\n{}", t.render());
}
