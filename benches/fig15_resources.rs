//! E6: regenerate Fig. 15 (per-FPGA resource utilisation).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("fig15: resource model over the 38-kernel cluster", || tables::fig15().unwrap());
    println!("\n{}", t.render());
    println!("paper shape: BRAM is the limiting resource (FIFOs sized to hold full matrices + all weights on-chip); DSP heavy on the linear/FFN FPGAs.");
}
