//! P1: hot-path performance benchmarks — the §Perf deliverable.
//!
//! Three layers per the optimization plan:
//!   L3 sim engine: events/s through the DES (the "testbed" itself)
//!   L3 functional compute: bit-exact integer encoder (rust native)
//!   runtime: PJRT encoder artifact latency (the serving path)

use std::sync::Arc;

use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
use galapagos_llm::ibert::encoder::{encoder_forward, rows_i8};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::weights::{load_golden, ModelParams};
use galapagos_llm::runtime::{EncoderEngine, PjrtRuntime};
use galapagos_llm::util::bench::{black_box, Bencher};

fn main() {
    let dir = ModelParams::default_dir();
    let params = Arc::new(ModelParams::load(&dir).unwrap());
    let x128 = rows_i8(load_golden(&dir, "input_m128").unwrap().as_i8().unwrap());
    let mut b = Bencher::default();

    // --- L3: discrete-event engine throughput ---
    for m in [38usize, 128] {
        let events = {
            let mut tb = build_testbed(&TestbedConfig::proof_of_concept(m, Mode::Timing)).unwrap();
            tb.sim.start();
            tb.sim.run().unwrap();
            tb.sim.trace.events_processed
        };
        let r = b.bench(&format!("sim: encoder timing run m={m} ({events} events)"), || {
            let mut tb =
                build_testbed(&TestbedConfig::proof_of_concept(m, Mode::Timing)).unwrap();
            tb.sim.start();
            black_box(tb.sim.run().unwrap());
        });
        let evps = events as f64 / (r.median_ns() / 1e9);
        println!("    -> {:.2} M events/s", evps / 1e6);
    }

    // --- L3: functional (bit-exact) simulation of the six-FPGA cluster ---
    {
        let input = Arc::new(x128[..38].to_vec());
        b.bench("sim: encoder FUNCTIONAL run m=38 (bit-exact payloads)", || {
            let mut cfg = TestbedConfig::proof_of_concept(38, Mode::Functional(params.clone()));
            cfg.input = Some(input.clone());
            let mut tb = build_testbed(&cfg).unwrap();
            tb.sim.start();
            black_box(tb.sim.run().unwrap());
        });
    }

    // --- native integer compute (the kernels' inner loops) ---
    for m in [38usize, 128] {
        b.bench(&format!("native: encoder_forward m={m}"), || {
            black_box(encoder_forward(&params, &x128[..m]));
        });
    }

    // --- runtime: PJRT artifact (request path) ---
    let rt = PjrtRuntime::cpu().unwrap();
    let engine = b.once("pjrt: compile encoder artifact (one-time)", || {
        EncoderEngine::load(&rt, &dir).unwrap()
    });
    for m in [38usize, 128] {
        b.bench(&format!("pjrt: encoder infer m={m}"), || {
            black_box(engine.infer(&x128[..m]).unwrap());
        });
    }

    println!("\n(record before/after in EXPERIMENTS.md §Perf)");
}
