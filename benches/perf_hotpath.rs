//! P1: hot-path performance benchmarks — the §Perf deliverable.
//!
//! Every hot path is measured in BOTH configurations so the speedup is
//! tracked, not asserted:
//!   L3 sim engine: events/s through the DES — reference (binary heap,
//!     per-row packets) vs optimized (calendar wheel + burst coalescing)
//!   L3 functional compute: bit-exact integer encoder — row-at-a-time
//!     reference vs cache-blocked + worker-pool forward
//!   runtime: PJRT encoder artifact latency (the serving path; needs
//!     `make artifacts`)
//!
//! `galapagos-llm bench --quick --out BENCH_hotpath.json` runs the same
//! suite headlessly and records the JSON trajectory.

use std::sync::Arc;

use galapagos_llm::eval::testbed::{build_testbed, TestbedConfig};
use galapagos_llm::ibert::config::ModelConfig;
use galapagos_llm::ibert::encoder::{encoder_forward, encoder_forward_reference, rows_i8};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::weights::{load_golden, synthetic_input, ModelParams};
use galapagos_llm::runtime::{EncoderEngine, PjrtRuntime};
use galapagos_llm::util::bench::{black_box, Bencher};

fn sim_pair(b: &mut Bencher, label: &str, cfg: &TestbedConfig) {
    let mut medians = [0.0f64; 2];
    for (i, reference) in [(0usize, true), (1, false)] {
        let mut tb = build_testbed(cfg).unwrap();
        if reference {
            tb.sim.reference_mode();
        }
        tb.sim.start();
        tb.sim.run().unwrap();
        let events = tb.sim.trace.events_processed;
        let variant = if reference { "reference" } else { "coalesced" };
        let r = b.bench(&format!("{label} [{variant}] ({events} events)"), || {
            let mut tb = build_testbed(cfg).unwrap();
            if reference {
                tb.sim.reference_mode();
            }
            tb.sim.start();
            black_box(tb.sim.run().unwrap());
        });
        let evps = events as f64 / (r.median_ns() / 1e9);
        medians[i] = r.median_ns();
        println!("    -> {:.2} M events/s", evps / 1e6);
    }
    println!("    -> engine speedup {:.2}x", medians[0] / medians[1].max(1.0));
}

fn main() {
    let mut b = Bencher::default();

    // --- L3: discrete-event engine throughput (timing mode) ---
    for m in [38usize, 128] {
        let cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
        sim_pair(&mut b, &format!("sim: encoder timing run m={m}"), &cfg);
    }

    // --- L3: functional (bit-exact) simulation ---
    {
        // synthetic model so the bench runs without `make artifacts`
        let cfg_small =
            ModelConfig { hidden: 96, heads: 12, ffn: 384, max_seq: 32, num_encoders: 1 };
        let params = Arc::new(ModelParams::synthetic(cfg_small, 0xBE9C4));
        let m = 24;
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(params));
        cfg.input = Some(Arc::new(synthetic_input(cfg_small.hidden, m, 7)));
        sim_pair(&mut b, &format!("sim: encoder FUNCTIONAL m={m} (h=96)"), &cfg);
    }

    // --- native integer compute (the kernels' inner loops) ---
    let dir = ModelParams::default_dir();
    let artifacts = ModelParams::load(&dir).ok();
    let (params, x128) = match &artifacts {
        Some(p) => (
            p.clone(),
            rows_i8(load_golden(&dir, "input_m128").unwrap().as_i8().unwrap()),
        ),
        None => {
            println!("(artifacts absent: native bench uses a synthetic ibert-base model)");
            let cfg = ModelConfig::default();
            (ModelParams::synthetic(cfg, 0xF00D), synthetic_input(cfg.hidden, 128, 11))
        }
    };
    for m in [38usize, 128] {
        let r = b.bench(&format!("native: encoder_forward m={m} [reference]"), || {
            black_box(encoder_forward_reference(&params, &x128[..m]));
        });
        let ref_ns = r.median_ns();
        let r = b.bench(&format!("native: encoder_forward m={m} [blocked+parallel]"), || {
            black_box(encoder_forward(&params, &x128[..m]));
        });
        let rows_s = m as f64 / (r.median_ns() / 1e9);
        println!(
            "    -> {:.0} rows/s, native speedup {:.2}x",
            rows_s,
            ref_ns / r.median_ns().max(1.0)
        );
    }

    // --- runtime: PJRT artifact (request path; artifacts only) ---
    if artifacts.is_some() {
        let rt = PjrtRuntime::cpu().unwrap();
        let engine = b.once("pjrt: compile encoder artifact (one-time)", || {
            EncoderEngine::load(&rt, &dir).unwrap()
        });
        for m in [38usize, 128] {
            b.bench(&format!("pjrt: encoder infer m={m}"), || {
                black_box(engine.infer(&x128[..m]).unwrap());
            });
        }
    } else {
        println!("(skipping pjrt bench: run `make artifacts` first)");
    }

    println!("\n(record before/after in BENCH_hotpath.json via `galapagos-llm bench`)");
}
