//! E2: regenerate Table 2 (estimated 12-encoder latency via Eq. 1).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("table2: Eq.1 over 8 sequence lengths", || tables::table2().unwrap());
    println!("\n{}", t.render());
    println!("note: the paper's published Table 2 equals Eq. 1 with d = 0; see EXPERIMENTS.md E2.");
}
