//! E5: regenerate Table 5 (throughput vs T4 / A100 at max seq 128).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("table5: throughput vs GPUs", || tables::table5().unwrap());
    println!("\n{}", t.render());
    println!("nuance (8.2.3): GPU throughput uses batch-128; each batched request then waits the full batch latency (T4: 80.95 ms) while the FPGA pipeline keeps batch-1 latency.");
}
