//! E10: §9.4 scalability / communication-overhead microbenchmarks.
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("scaling: routing state + fabric latencies", || tables::scaling_table().unwrap());
    println!("\n{}", t.render());
}
