//! E7: regenerate Fig. 16 (per-layer latency vs sequence length).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("fig16: standalone per-layer latency sweep", || {
        tables::fig16(&[1, 2, 4, 8, 16, 32, 64, 128]).unwrap()
    });
    println!("\n{}", t.render());
}
