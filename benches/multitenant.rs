//! Multi-tenant isolation bench: the `--check`-gated headline behind the
//! PR 10 tenant stack, recorded in BENCH_multitenant.json (the perf-smoke
//! CI job uploads the quick run, like BENCH_batching.json tracks the
//! iteration scheduler).
//!
//!   cargo bench --bench multitenant            # full run
//!   cargo bench --bench multitenant -- --quick # CI smoke
//!   ... -- --check [--tolerance 0.35]          # regression gate
//!
//! Scenario: a guaranteed-class "chat" tenant (2 encoders, 900 us SLO)
//! serves the same seed-stream schedule twice — once alone on the fleet,
//! once next to a bursty best-effort neighbor pushing ~20x chat's rate
//! through its own 1-encoder chain. The placer gives each tenant disjoint
//! FPGAs, so the only shared resources are the evaluation FPGA's egress
//! NIC and the switch fabric; the headline
//! `multitenant_isolation_p99_ratio` (solo p99 / mixed p99, 1.0 = the
//! neighbor moved nothing) commits how much of chat's p99 the burst is
//! allowed to take. The mixed point also re-runs at threads=1 vs
//! threads=N on both shard granularities with byte-equality asserted —
//! the determinism contract extends to multi-tenant serving.

use galapagos_llm::serve::tenant::{TenantClass, TenantSpec, TenantsConfig};
use galapagos_llm::serve::{
    run_multi_tenant_serving, ArrivalProcess, LengthDist, MultiTenantConfig,
};
use galapagos_llm::util::bench::Bencher;
use galapagos_llm::util::json::Json;
use galapagos_llm::{cycles_to_us, util::cli::Args};

fn chat(requests: usize) -> TenantSpec {
    TenantSpec {
        name: "chat".into(),
        encoders: 2,
        class: TenantClass::Guaranteed,
        slo_p99_us: 900.0,
        kv_slots: 8,
        requests,
        process: ArrivalProcess::Poisson { seqs_per_s: 5_000.0 },
        lengths: LengthDist::Glue,
        max_m: 64,
    }
}

fn burst(requests: usize) -> TenantSpec {
    TenantSpec {
        name: "burst".into(),
        encoders: 1,
        class: TenantClass::BestEffort,
        slo_p99_us: 400.0,
        kv_slots: 16,
        requests,
        process: ArrivalProcess::Poisson { seqs_per_s: 100_000.0 },
        lengths: LengthDist::Mrpc,
        max_m: 32,
    }
}

fn roster(specs: Vec<TenantSpec>) -> TenantsConfig {
    TenantsConfig { interval: 12, fpgas_per_switch: 6, tenants: specs }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.bool_or("quick", false)?;
    let out_path = args.str_or("out", "BENCH_multitenant.json");
    let seed = args.u64_or("seed", 7)?;
    let chat_reqs = args.usize_or("requests", if quick { 16 } else { 32 })?;
    let burst_reqs = chat_reqs * 3;
    let mut b = Bencher::quick();

    // chat is tenant index 0 in both rosters, so stream_seed gives it the
    // SAME offered schedule (and admission outcome) alone and mixed —
    // the comparison isolates fabric interference, not traffic drift
    let solo_cfg = MultiTenantConfig::new(roster(vec![chat(chat_reqs)]), seed);
    let solo = b.once("chat alone on the fleet", || run_multi_tenant_serving(&solo_cfg))?;
    let mixed_cfg =
        MultiTenantConfig::new(roster(vec![chat(chat_reqs), burst(burst_reqs)]), seed);
    let mixed =
        b.once("chat + bursty best-effort neighbor", || run_multi_tenant_serving(&mixed_cfg))?;

    let solo_chat = &solo.tenants.as_ref().expect("v6 report")[0];
    let mixed_tenants = mixed.tenants.as_ref().expect("v6 report");
    let (mixed_chat, mixed_burst) = (&mixed_tenants[0], &mixed_tenants[1]);
    anyhow::ensure!(
        solo_chat.admitted == mixed_chat.admitted && solo_chat.offered == mixed_chat.offered,
        "chat's schedule moved with the roster: {}/{} solo vs {}/{} mixed",
        solo_chat.admitted,
        solo_chat.offered,
        mixed_chat.admitted,
        mixed_chat.offered
    );
    anyhow::ensure!(
        solo_chat.completed == solo_chat.admitted && mixed_chat.completed == mixed_chat.admitted,
        "chat dropped admitted requests (solo {}/{}, mixed {}/{})",
        solo_chat.completed,
        solo_chat.admitted,
        mixed_chat.completed,
        mixed_chat.admitted
    );
    anyhow::ensure!(
        mixed_burst.completed == mixed_burst.admitted,
        "burst dropped admitted requests ({}/{})",
        mixed_burst.completed,
        mixed_burst.admitted
    );

    let ratio = solo_chat.latency.p99 as f64 / mixed_chat.latency.p99.max(1) as f64;
    let fairness = mixed.fairness.as_ref().expect("v6 report");
    println!(
        "\nchat p99: {:.1} us alone -> {:.1} us next to the burst \
         (isolation ratio {ratio:.3}; jain {:.3}, worst tenant {} at {:.2}x SLO)",
        cycles_to_us(solo_chat.latency.p99),
        cycles_to_us(mixed_chat.latency.p99),
        fairness.jain_index,
        fairness.worst_tenant,
        fairness.max_p99_over_slo
    );
    // loose in-bench sanity; the committed BENCH_multitenant.json floor
    // is the real bound and --check gates against it
    anyhow::ensure!(
        ratio >= 0.5,
        "bursty neighbor doubled the guaranteed tenant's p99 (ratio {ratio:.3})"
    );

    // bit-identity at the mixed point: threads=1 vs threads=N on both
    // shard cuts (the crown-jewel contract extends to tenant rosters)
    let threads = galapagos_llm::util::pool::sim_threads().max(2);
    let mut seq_cfg = mixed_cfg.clone();
    seq_cfg.threads = Some(1);
    let seq = run_multi_tenant_serving(&seq_cfg)?;
    for g in [
        galapagos_llm::sim::ShardGranularity::PerCluster,
        galapagos_llm::sim::ShardGranularity::PerFpga,
    ] {
        let mut par_cfg = mixed_cfg.clone();
        par_cfg.threads = Some(threads);
        par_cfg.granularity = Some(g);
        let par = run_multi_tenant_serving(&par_cfg)?;
        anyhow::ensure!(
            seq.to_json().pretty() == par.to_json().pretty(),
            "multi-tenant report diverged at threads={threads} ({g:?})"
        );
    }
    println!("multi-tenant reports identical at 1 vs {threads} threads, both shard granularities");

    let mut cases: Vec<Json> = Vec::new();
    for (scenario, report) in [("chat solo", &solo), ("chat + burst", &mixed)] {
        let mut case = match report.to_json() {
            Json::Obj(kv) => kv,
            _ => unreachable!("report serializes to an object"),
        };
        case.insert(0, ("scenario".into(), Json::Str(scenario.into())));
        cases.push(Json::Obj(case));
    }
    let headlines: Vec<(String, f64)> = vec![
        ("multitenant_isolation_p99_ratio".into(), ratio),
        ("multitenant_jain_index".into(), fairness.jain_index),
        (
            "multitenant_guaranteed_delivered_fraction".into(),
            mixed_chat.delivered_fraction(),
        ),
    ];
    let doc = Json::obj(vec![
        ("schema", Json::Str("bench_multitenant/v1".into())),
        ("mode", Json::Str(if quick { "quick" } else { "full" }.into())),
        ("seed", Json::Num(seed as f64)),
        ("chat_requests", Json::Num(chat_reqs as f64)),
        ("burst_requests", Json::Num(burst_reqs as f64)),
        ("sim_threads", Json::Num(galapagos_llm::util::pool::sim_threads() as f64)),
        ("cases", Json::Arr(cases)),
        (
            "headlines",
            Json::Obj(headlines.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
        ),
    ]);

    // --check: read the committed baseline before overwriting it
    let regressions = galapagos_llm::util::bench::load_check(&args, &doc, &out_path)?;
    std::fs::write(&out_path, doc.pretty())?;
    println!("\nwrote {out_path}");
    galapagos_llm::util::bench::report_check(regressions)?;
    Ok(())
}
