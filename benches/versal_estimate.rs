//! E9: regenerate the §9.3 Versal estimate.
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("versal: \u{a7}9.3 estimate", || tables::versal_table().unwrap());
    println!("\n{}", t.render());
}
