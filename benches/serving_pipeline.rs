//! Serving-pipeline bench: stream open-loop traffic through multi-encoder
//! chains across a scenario matrix and record the serving trajectory in
//! BENCH_serving.json (the perf-smoke CI job uploads the quick run, like
//! BENCH_hotpath.json tracks the engine hot paths).
//!
//!   cargo bench --bench serving_pipeline            # full matrix
//!   cargo bench --bench serving_pipeline -- --quick # CI smoke
//!   ... -- --check [--tolerance 0.35]               # regression gate
//!
//! Scenarios cover both arrival processes, the three length
//! distributions (SQuAD clamped to the 128-token build), chain depths up
//! to the full 12-encoder I-BERT, and a deliberate overload point whose
//! tail latency documents the open-loop queueing behavior. The
//! 12-encoder scenario additionally runs at threads=1 vs threads=N to
//! record the sharded-engine speedup headline (asserting report
//! equality on the way — the parallel engine is trace-identical by
//! contract); `--check` compares all headlines against the committed
//! BENCH_serving.json and exits nonzero on regression.

use galapagos_llm::serve::{run_serving, ArrivalProcess, LengthDist, ServeConfig, ServingReport};
use galapagos_llm::util::bench::Bencher;
use galapagos_llm::util::json::Json;
use galapagos_llm::{cycles_to_us, util::cli::Args};

struct Scenario {
    name: &'static str,
    encoders: usize,
    lengths: LengthDist,
    uniform: bool,
    /// offered load as a fraction of the measured pipeline capacity
    load: f64,
    requests: usize,
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.bool_or("quick", false)?;
    let out_path = args.str_or("out", "BENCH_serving.json");
    let seed = args.u64_or("seed", 7)?;
    let mut b = Bencher::quick();

    let scenarios = [
        Scenario {
            name: "glue poisson 6enc 70%",
            encoders: 6,
            lengths: LengthDist::Glue,
            uniform: false,
            load: 0.7,
            requests: 200,
        },
        Scenario {
            name: "glue poisson 12enc 70%",
            encoders: 12,
            lengths: LengthDist::Glue,
            uniform: false,
            load: 0.7,
            requests: 160,
        },
        Scenario {
            name: "mrpc uniform 6enc 50%",
            encoders: 6,
            lengths: LengthDist::Mrpc,
            uniform: true,
            load: 0.5,
            requests: 160,
        },
        Scenario {
            name: "squad(clamp128) poisson 6enc 50%",
            encoders: 6,
            lengths: LengthDist::Squad,
            uniform: false,
            load: 0.5,
            requests: 120,
        },
        Scenario {
            name: "glue poisson 6enc 180% (overload)",
            encoders: 6,
            lengths: LengthDist::Glue,
            uniform: false,
            load: 1.8,
            requests: 120,
        },
    ];

    let mut cases: Vec<Json> = Vec::new();
    let mut headlines: Vec<(String, f64)> = Vec::new();
    for s in &scenarios {
        let requests = if quick { (s.requests / 8).max(12) } else { s.requests };
        let mut cfg = ServeConfig::glue(s.encoders, requests, 1.0, seed);
        cfg.traffic.lengths = s.lengths;
        cfg.check_eq1 = true;
        let (_mean_m, capacity) = cfg.capacity_at_mean()?;
        let rate = capacity * s.load;
        cfg.traffic.process = if s.uniform {
            ArrivalProcess::Uniform { seqs_per_s: rate }
        } else {
            ArrivalProcess::Poisson { seqs_per_s: rate }
        };

        let t0 = std::time::Instant::now();
        let report = b.once(s.name, || run_serving(&cfg))?;
        let wall = t0.elapsed();
        println!(
            "    p50 {:>8.1} us  p95 {:>8.1} us  p99 {:>8.1} us   {:>7.0} seqs/s  \
             {:>9.0} tokens/s   eq1 {:+.2}%",
            cycles_to_us(report.latency.p50),
            cycles_to_us(report.latency.p95),
            cycles_to_us(report.latency.p99),
            report.seqs_per_s(),
            report.tokens_per_s(),
            report.eq1.map(|e| 100.0 * e.rel_err()).unwrap_or(f64::NAN),
        );
        let mut case = match report.to_json() {
            Json::Obj(kv) => kv,
            _ => unreachable!("report serializes to an object"),
        };
        case.insert(0, ("scenario".into(), Json::Str(s.name.into())));
        case.push(("capacity_seqs_per_s".into(), Json::Num(capacity)));
        case.push(("load".into(), Json::Num(s.load)));
        case.push(("wall_ms".into(), Json::Num(wall.as_secs_f64() * 1e3)));
        case.push((
            "events_per_s".into(),
            Json::Num(report.events as f64 / wall.as_secs_f64().max(1e-9)),
        ));
        cases.push(Json::Obj(case));

        // the deep-chain scenario doubles as the sharded-engine speedup
        // headline: threads=1 vs threads=N on the identical workload,
        // with a report-equality assertion (trace-identity contract)
        if s.encoders == 12 {
            let threads = galapagos_llm::util::pool::sim_threads().max(2);
            // best-of-3 walls per engine (matches the Bencher-median
            // spirit of the other headlines; a single cold sample is too
            // noisy to gate --check on)
            let run_best = |n: usize| -> anyhow::Result<(f64, ServingReport)> {
                let mut cfg = cfg.clone();
                cfg.threads = Some(n);
                let mut best = f64::INFINITY;
                let mut last = None;
                for _ in 0..3 {
                    let t0 = std::time::Instant::now();
                    last = Some(run_serving(&cfg)?);
                    best = best.min(t0.elapsed().as_secs_f64());
                }
                Ok((best, last.unwrap()))
            };
            let (seq_wall, seq) = run_best(1)?;
            let (par_wall, par) = run_best(threads)?;
            anyhow::ensure!(
                seq.to_json().pretty() == par.to_json().pretty(),
                "parallel serving report diverged from sequential at threads={threads}"
            );
            let speedup = seq_wall / par_wall.max(1e-9);
            println!(
                "    sharded engine: {:.0} -> {:.0} events/s at {threads} threads \
                 ({speedup:.2}x best-of-3, reports identical)",
                seq.events as f64 / seq_wall.max(1e-9),
                par.events as f64 / par_wall.max(1e-9),
            );
            headlines.push(("parallel_serving_12enc_speedup".into(), speedup));
        }
    }

    let doc = Json::obj(vec![
        ("schema", Json::Str("bench_serving/v1".into())),
        ("mode", Json::Str(if quick { "quick" } else { "full" }.into())),
        ("seed", Json::Num(seed as f64)),
        ("sim_threads", Json::Num(galapagos_llm::util::pool::sim_threads() as f64)),
        ("cases", Json::Arr(cases)),
        (
            "headlines",
            Json::Obj(headlines.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
        ),
    ]);

    // --check: read the committed baseline before overwriting it
    let regressions = galapagos_llm::util::bench::load_check(&args, &doc, &out_path)?;
    std::fs::write(&out_path, doc.pretty())?;
    println!("\nwrote {out_path}");
    galapagos_llm::util::bench::report_check(regressions)?;
    Ok(())
}
