//! E1: regenerate Table 1 (encoder latency components X/T/I vs seq len).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("table1: X/T/I sweep over 8 sequence lengths", || tables::table1().unwrap());
    println!("\n{}", t.render());
    b.bench("single encoder sim (m=128, timing mode)", || {
        galapagos_llm::util::bench::black_box(tables::measure_components(128).unwrap());
    });
}
