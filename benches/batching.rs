//! Continuous-batching bench: the throughput–latency Pareto sweep behind
//! the iteration-level scheduler (PR 9), recorded in BENCH_batching.json
//! (the perf-smoke CI job uploads the quick run, like BENCH_decode.json
//! tracks unbatched generation).
//!
//!   cargo bench --bench batching            # full matrix
//!   cargo bench --bench batching -- --quick # CI smoke
//!   ... -- --check [--tolerance 0.35]       # regression gate
//!
//! Operating point: one encoder, short prompts (max_m = 8) and 24
//! generated tokens per request, so the run is decode-dominated — the
//! regime where grouping token rows into one weight-stationary pass
//! pays. The sweep crosses batch caps B in {1, 2, 4, 8, 16} with several
//! offered rates; every case records simulated tokens/s against request
//! p99 + TTFT/ITL percentiles (one Pareto point each). B = 1 is the
//! exact legacy v4 path and serves as the speedup denominator; the
//! saturated B = 8 point is the `--check`-gated headline. The headline
//! point also re-runs at threads=1 vs threads=N on both shard
//! granularities with byte-equality asserted: batching rides the same
//! conservative sharded engine as everything else.

use galapagos_llm::serve::{
    run_serving, ArrivalProcess, BatchConfig, DecodeConfig, LengthDist, ServeConfig, ServingReport,
};
use galapagos_llm::util::bench::Bencher;
use galapagos_llm::util::json::Json;
use galapagos_llm::{cycles_to_us, util::cli::Args, FABRIC_CLOCK_HZ};

const MAX_NEW_TOKENS: u32 = 24;
const WINDOW: u64 = 256;

fn batched_cfg(requests: usize, seed: u64, rate: f64, batch_max: u32) -> ServeConfig {
    let mut cfg = ServeConfig::glue(1, requests, rate, seed);
    cfg.traffic.lengths = LengthDist::Glue;
    cfg.traffic.max_m = 8; // short prompts: decode-dominated serving
    cfg.decode = Some(DecodeConfig { max_new_tokens: MAX_NEW_TOKENS });
    if batch_max >= 2 {
        cfg.batching = Some(BatchConfig { max: batch_max, window: WINDOW });
    }
    cfg
}

fn tokens_per_s(r: &ServingReport) -> f64 {
    let generated = r.decode.as_ref().map_or(0, |d| d.generated_tokens);
    generated as f64 * FABRIC_CLOCK_HZ as f64 / r.makespan_cycles.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.bool_or("quick", false)?;
    let out_path = args.str_or("out", "BENCH_batching.json");
    let seed = args.u64_or("seed", 7)?;
    let requests = args.usize_or("requests", if quick { 16 } else { 48 })?;
    let mut b = Bencher::quick();

    // offered load as a fraction of the measured PREFILL capacity; the
    // 24 token passes per request sit on top, so 1.0 already saturates
    // the unbatched decoder and 3.0 keeps the batch assembler fed
    let loads: &[f64] = &[0.25, 1.0, 3.0];
    let batch_caps: &[u32] = &[1, 2, 4, 8, 16];
    let (_mean_m, capacity) = batched_cfg(requests, seed, 1.0, 1).capacity_at_mean()?;

    let mut cases: Vec<Json> = Vec::new();
    let mut headlines: Vec<(String, f64)> = Vec::new();
    let (mut base_b1_saturated, mut best_b8_saturated) = (None, None);
    for &load in loads {
        let rate = capacity * load;
        let mut unbatched = f64::NAN;
        for &cap in batch_caps {
            let cfg = batched_cfg(requests, seed, rate, cap);
            let name = format!("glue 1enc n{MAX_NEW_TOKENS} load {load:.2} B{cap}");
            let report = b.once(&name, || run_serving(&cfg))?;
            anyhow::ensure!(
                report.completed == report.requests,
                "{name}: {}/{} requests completed",
                report.completed,
                report.requests
            );
            let tps = tokens_per_s(&report);
            if cap == 1 {
                unbatched = tps;
            }
            let d = report.decode.as_ref().expect("decode section");
            let mean_size = report.batching.as_ref().map_or(1.0, |bb| bb.mean_batch_size());
            println!(
                "    {tps:>9.0} tokens/s  p99 {:>8.1} us  TTFT p50 {:>7.1} us  \
                 ITL p50 {:>6.1} us  mean batch {mean_size:.2}  ({:.2}x vs B1)",
                cycles_to_us(report.latency.p99),
                cycles_to_us(d.ttft.p50),
                cycles_to_us(d.itl.p50),
                tps / unbatched.max(1e-9),
            );
            // one Pareto point: simulated throughput vs latency tails
            let mut case = match report.to_json() {
                Json::Obj(kv) => kv,
                _ => unreachable!("report serializes to an object"),
            };
            case.insert(0, ("scenario".into(), Json::Str(name.clone())));
            case.push(("batch_max".into(), Json::Num(cap as f64)));
            case.push(("load".into(), Json::Num(load)));
            case.push(("capacity_seqs_per_s".into(), Json::Num(capacity)));
            case.push(("tokens_per_s".into(), Json::Num(tps)));
            case.push(("speedup_vs_b1".into(), Json::Num(tps / unbatched.max(1e-9))));
            cases.push(Json::Obj(case));

            if load >= 3.0 && cap == 1 {
                base_b1_saturated = Some(tps);
            }
            if load >= 3.0 && cap == 8 {
                best_b8_saturated = Some((tps, cfg));
            }
        }
    }

    // the headline: saturated B=8 throughput over the same-rate legacy
    // B=1 run — the amortized weight pass must actually pay
    let (b8_tps, b8_cfg) =
        best_b8_saturated.expect("the sweep always runs the saturated B=8 point");
    let b1_tps = base_b1_saturated.expect("the sweep always runs the saturated B=1 point");
    let speedup = b8_tps / b1_tps.max(1e-9);
    println!("\nbatched B=8 speedup at saturation: {speedup:.2}x ({b1_tps:.0} -> {b8_tps:.0} tokens/s)");
    anyhow::ensure!(
        speedup >= 1.2,
        "continuous batching stopped paying: B=8 speedup {speedup:.2}x < 1.2x"
    );
    headlines.push(("batched_tokens_per_s_speedup_b8".into(), speedup));
    headlines.push(("batched_tokens_per_s_b8".into(), b8_tps));

    // bit-identity at the headline point: threads=1 vs threads=N on both
    // shard cuts (the crown-jewel contract extends to the assembler)
    let threads = galapagos_llm::util::pool::sim_threads().max(2);
    let mut seq_cfg = b8_cfg.clone();
    seq_cfg.threads = Some(1);
    let seq = run_serving(&seq_cfg)?;
    for g in [
        galapagos_llm::sim::ShardGranularity::PerCluster,
        galapagos_llm::sim::ShardGranularity::PerFpga,
    ] {
        let mut par_cfg = b8_cfg.clone();
        par_cfg.threads = Some(threads);
        par_cfg.granularity = Some(g);
        let par = run_serving(&par_cfg)?;
        anyhow::ensure!(
            seq.to_json().pretty() == par.to_json().pretty(),
            "batched report diverged at threads={threads} ({g:?})"
        );
    }
    println!("batched reports identical at 1 vs {threads} threads, both shard granularities");

    let doc = Json::obj(vec![
        ("schema", Json::Str("bench_batching/v1".into())),
        ("mode", Json::Str(if quick { "quick" } else { "full" }.into())),
        ("seed", Json::Num(seed as f64)),
        ("requests", Json::Num(requests as f64)),
        ("max_new_tokens", Json::Num(MAX_NEW_TOKENS as f64)),
        ("batch_window_cycles", Json::Num(WINDOW as f64)),
        ("sim_threads", Json::Num(galapagos_llm::util::pool::sim_threads() as f64)),
        ("cases", Json::Arr(cases)),
        (
            "headlines",
            Json::Obj(headlines.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
        ),
    ]);

    // --check: read the committed baseline before overwriting it
    let regressions = galapagos_llm::util::bench::load_check(&args, &doc, &out_path)?;
    std::fs::write(&out_path, doc.pretty())?;
    println!("\nwrote {out_path}");
    galapagos_llm::util::bench::report_check(regressions)?;
    Ok(())
}
