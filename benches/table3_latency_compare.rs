//! E3: regenerate Table 3 (batch-1 latency vs T4 / A100 / NPE).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("table3: latency comparison", || tables::table3().unwrap());
    println!("\n{}", t.render());
    let (at_mean, over_dist) = tables::glue_average_latency_ms().unwrap();
    println!("no-padding GLUE latency: {:.2} ms at the mean length (paper method), {:.2} ms averaged over the length distribution", at_mean, over_dist);
}
