//! Fleet-scale bench: the thousand-FPGA lossy scenario (28 chains x 6
//! encoders x 6 FPGAs + the evaluation FPGA = 1009) run sequentially and
//! at 8 worker threads, recorded in BENCH_fleetscale.json.
//!
//!   cargo bench --bench fleetscale            # full 1009-FPGA trace
//!   cargo bench --bench fleetscale -- --quick # CI smoke (253 FPGAs)
//!   ... -- --check [--tolerance 0.5]          # regression gate
//!
//! Headline: `fleetscale_lossy_1000fpga_parallel_speedup` — events/s at
//! 8 threads over the sequential engine on the same lossy reliable
//! scenario. The two runs must also agree bit-for-bit (rows, cycles,
//! drops, retransmits): speed that changes the answer is not speed.

use galapagos_llm::eval::fleet::{run_fleet, FleetConfig, FleetReport};
use galapagos_llm::eval::testbed::NetworkConfig;
use galapagos_llm::util::bench::Bencher;
use galapagos_llm::util::cli::Args;
use galapagos_llm::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.bool_or("quick", false)?;
    let out_path = args.str_or("out", "BENCH_fleetscale.json");
    let seed = args.u64_or("seed", 7)?;
    let mut b = Bencher::quick();

    let mut cfg = FleetConfig::thousand_fpga();
    if quick {
        // same shape, a quarter of the chains: 7 x 6 x 6 + 1 = 253 FPGAs
        cfg.chains = 7;
    }
    cfg.net = NetworkConfig { drop_probability: 0.01, reliable: true, seed };
    println!(
        "fleet: {} chains x {} encoders = {} clusters, {} FPGAs, 1% loss + reliable transport",
        cfg.chains,
        cfg.encoders_per_chain,
        cfg.chains * cfg.encoders_per_chain,
        cfg.total_fpgas(),
    );

    let mut cases: Vec<Json> = Vec::new();
    let mut run_at = |b: &mut Bencher,
                      name: &str,
                      threads: usize|
     -> anyhow::Result<(FleetReport, f64)> {
        let mut c = cfg.clone();
        c.threads = Some(threads);
        let t0 = std::time::Instant::now();
        let (report, _fleet) = b.once(name, || run_fleet(&c))?;
        let wall_s = t0.elapsed().as_secs_f64();
        let events_per_sec = report.events as f64 / wall_s.max(1e-9);
        anyhow::ensure!(
            report.completed() && !report.truncated,
            "{name}: reliable transport must deliver every row ({}/{} rows)",
            report.rows,
            report.expected_rows
        );
        anyhow::ensure!(report.dropped > 0, "{name}: the 1% lossy run must drop something");
        println!(
            "  {name}: {} rows, end cycle {}, {} events ({:.2} M events/s), \
             {} dropped / {} retransmitted",
            report.rows,
            report.end_cycle,
            report.events,
            events_per_sec / 1e6,
            report.dropped,
            report.retransmits,
        );
        cases.push(Json::obj(vec![
            ("scenario", Json::Str(name.into())),
            ("threads", Json::Num(threads as f64)),
            ("fpgas", Json::Num(report.fpgas as f64)),
            ("rows", Json::Num(report.rows as f64)),
            ("end_cycle", Json::Num(report.end_cycle as f64)),
            ("events", Json::Num(report.events as f64)),
            ("dropped", Json::Num(report.dropped as f64)),
            ("retransmits", Json::Num(report.retransmits as f64)),
            ("events_per_sec", Json::Num(events_per_sec)),
            ("wall_ms", Json::Num(wall_s * 1e3)),
        ]));
        Ok((report, events_per_sec))
    };

    let (seq, seq_eps) = run_at(&mut b, "lossy fleet, sequential", 1)?;
    let (par, par_eps) = run_at(&mut b, "lossy fleet, 8 threads", 8)?;
    anyhow::ensure!(
        (seq.rows, seq.end_cycle, seq.events, seq.dropped, seq.retransmits)
            == (par.rows, par.end_cycle, par.events, par.dropped, par.retransmits),
        "parallel run diverged from sequential: {seq:?} vs {par:?}"
    );
    let speedup = par_eps / seq_eps.max(1e-9);
    println!("  parallel speedup: {speedup:.2}x events/s at 8 threads");

    let doc = Json::obj(vec![
        ("schema", Json::Str("bench_fleetscale/v1".into())),
        ("mode", Json::Str(if quick { "quick" } else { "full" }.into())),
        ("seed", Json::Num(seed as f64)),
        ("chains", Json::Num(cfg.chains as f64)),
        ("fpgas", Json::Num(cfg.total_fpgas() as f64)),
        ("cases", Json::Arr(cases)),
        (
            "headlines",
            Json::obj(vec![(
                "fleetscale_lossy_1000fpga_parallel_speedup",
                Json::Num(speedup),
            )]),
        ),
    ]);

    // --check: read any committed baseline before overwriting it
    let regressions = galapagos_llm::util::bench::load_check(&args, &doc, &out_path)?;
    std::fs::write(&out_path, doc.pretty())?;
    println!("\nwrote {out_path}");
    galapagos_llm::util::bench::report_check(regressions)?;
    Ok(())
}
