//! E4: regenerate Table 4 (throughput vs FTRANS / NPE at max seq 64).
use galapagos_llm::eval::tables;
use galapagos_llm::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let t = b.once("table4: throughput vs prior FPGA accelerators", || tables::table4().unwrap());
    println!("\n{}", t.render());
}
