//! Failover bench: degraded-mode serving scenarios — lossy UDP with and
//! without reliable transport, and a mid-serving FPGA failure with
//! recovery re-placement — recorded in BENCH_failover.json (the
//! perf-smoke CI job uploads the quick run alongside BENCH_hotpath.json
//! and BENCH_serving.json).
//!
//!   cargo bench --bench failover            # full trace
//!   cargo bench --bench failover -- --quick # CI smoke
//!   ... -- --check [--tolerance 0.5]        # regression gate
//!
//! Headlines: time-to-recover for the §6 failover, the degraded-mode
//! (outage-window) p99, the reliable-lossy p99, and the completed
//! fraction of each scenario. The failover scenario uses a compressed
//! 150k-cycle reconfiguration window so the trace stays bench-sized; the
//! device's full-bitstream default (~22.5M cycles on an XCZU19EG) is
//! recorded in the JSON for scale.

use galapagos_llm::eval::testbed::FailureSchedule;
use galapagos_llm::fpga::resources::Device;
use galapagos_llm::placer::ReconfigModel;
use galapagos_llm::serve::{run_serving, ArrivalProcess, ServeConfig};
use galapagos_llm::util::bench::Bencher;
use galapagos_llm::util::json::Json;
use galapagos_llm::{cycles_to_us, util::cli::Args, FABRIC_CLOCK_HZ};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.bool_or("quick", false)?;
    let out_path = args.str_or("out", "BENCH_failover.json");
    let seed = args.u64_or("seed", 7)?;
    let mut b = Bencher::quick();

    let encoders = if quick { 3 } else { 6 };
    let requests = if quick { 16 } else { 64 };
    let mut base = ServeConfig::glue(encoders, requests, 1.0, seed);
    let (mean_m, capacity) = base.capacity_at_mean()?;
    let rate = capacity * 0.5;
    base.traffic.process = ArrivalProcess::Uniform { seqs_per_s: rate };
    println!("pipeline capacity ~{capacity:.0} seqs/s at m={mean_m}; offering {rate:.0} seqs/s");

    let mut cases: Vec<Json> = Vec::new();
    let mut headlines: Vec<(String, f64)> = Vec::new();
    let record = |name: &str,
                  cases: &mut Vec<Json>,
                  report: &galapagos_llm::serve::ServingReport,
                  wall_ms: f64| {
        println!(
            "  {name}: {}/{} completed   p50 {:>8.1} us  p99 {:>8.1} us   \
             {} dropped / {} retransmitted",
            report.completed,
            report.requests,
            cycles_to_us(report.latency.p50),
            cycles_to_us(report.latency.p99),
            report.dropped,
            report.retransmits,
        );
        let mut case = match report.to_json() {
            Json::Obj(kv) => kv,
            _ => unreachable!("report serializes to an object"),
        };
        case.insert(0, ("scenario".into(), Json::Str(name.into())));
        case.push(("wall_ms".into(), Json::Num(wall_ms)));
        cases.push(Json::Obj(case));
    };

    // --- clean baseline (the healthy-pipeline p99 the others compare to)
    {
        let t0 = std::time::Instant::now();
        let r = b.once("clean baseline", || run_serving(&base))?;
        record("clean baseline", &mut cases, &r, t0.elapsed().as_secs_f64() * 1e3);
        anyhow::ensure!(r.completed == r.requests, "clean run must complete everything");
        headlines.push(("clean_p99_us".into(), cycles_to_us(r.latency.p99)));
    }

    // --- 2% loss, unreliable: the paper's raw-UDP posture under stress
    {
        let mut cfg = base.clone();
        cfg.drop_probability = 0.02;
        let t0 = std::time::Instant::now();
        let r = b.once("2% loss, unreliable", || run_serving(&cfg))?;
        record("2% loss unreliable", &mut cases, &r, t0.elapsed().as_secs_f64() * 1e3);
        headlines.push((
            "lossy_unreliable_completed_fraction".into(),
            r.completed as f64 / r.requests.max(1) as f64,
        ));
    }

    // --- 2% loss + reliable transport: 100% completion, tail pays retries
    {
        let mut cfg = base.clone();
        cfg.drop_probability = 0.02;
        cfg.reliable = true;
        let t0 = std::time::Instant::now();
        let r = b.once("2% loss, reliable", || run_serving(&cfg))?;
        record("2% loss reliable", &mut cases, &r, t0.elapsed().as_secs_f64() * 1e3);
        anyhow::ensure!(
            r.completed == r.requests,
            "reliable transport must complete every inference ({}/{})",
            r.completed,
            r.requests
        );
        headlines.push(("lossy_reliable_p99_us".into(), cycles_to_us(r.latency.p99)));
    }

    // --- mid-serving FPGA failure + recovery re-placement (§6)
    {
        let mut cfg = base.clone();
        // fail an attention-stage FPGA of encoder 0 a third of the way in
        let expected_makespan =
            (requests as f64 * FABRIC_CLOCK_HZ as f64 / rate).round() as u64;
        let reconfig = 150_000u64;
        cfg.fail = Some(FailureSchedule {
            fpga: 2,
            at_cycle: expected_makespan / 3,
            recovery_cycles: Some(reconfig),
        });
        let t0 = std::time::Instant::now();
        let r = b.once("failover", || run_serving(&cfg))?;
        record("failover", &mut cases, &r, t0.elapsed().as_secs_f64() * 1e3);
        let f = r.fault.clone().expect("fault section present");
        println!(
            "    time-to-recover {:.2} ms, {} kernels re-placed{}, {} pkts buffered, \
             {} requests lost",
            cycles_to_us(f.time_to_recover_cycles()) / 1e3,
            f.moved_kernels,
            if f.degraded_placement { " (degraded)" } else { "" },
            f.held_packets,
            f.incomplete_requests,
        );
        headlines.push((
            "time_to_recover_us".into(),
            cycles_to_us(f.time_to_recover_cycles()),
        ));
        let degraded_p99 = f.recovery_window.map(|w| w.p99).unwrap_or(0);
        headlines.push(("failover_degraded_p99_us".into(), cycles_to_us(degraded_p99)));
        headlines.push((
            "failover_completed_fraction".into(),
            r.completed as f64 / r.requests.max(1) as f64,
        ));
    }

    let doc = Json::obj(vec![
        ("schema", Json::Str("bench_failover/v1".into())),
        ("mode", Json::Str(if quick { "quick" } else { "full" }.into())),
        ("seed", Json::Num(seed as f64)),
        ("encoders", Json::Num(encoders as f64)),
        (
            "reconfig_model_default_cycles",
            Json::Num(ReconfigModel::for_device(Device::Xczu19eg).cycles() as f64),
        ),
        ("cases", Json::Arr(cases)),
        (
            "headlines",
            Json::Obj(headlines.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
        ),
    ]);

    // --check: read any committed baseline before overwriting it
    let regressions = galapagos_llm::util::bench::load_check(&args, &doc, &out_path)?;
    std::fs::write(&out_path, doc.pretty())?;
    println!("\nwrote {out_path}");
    galapagos_llm::util::bench::report_check(regressions)?;
    Ok(())
}
