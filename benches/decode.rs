//! Decode-serving bench: autoregressive (prefill + per-token feedback)
//! traffic through multi-encoder chains, recording the generative-serving
//! trajectory in BENCH_decode.json (the perf-smoke CI job uploads the
//! quick run, like BENCH_serving.json tracks prefill-only serving).
//!
//!   cargo bench --bench decode            # full matrix
//!   cargo bench --bench decode -- --quick # CI smoke
//!   ... -- --check [--tolerance 0.35]     # regression gate
//!
//! Scenarios vary chain depth and tokens-per-request; every case records
//! TTFT/ITL percentiles and the simulated decode throughput (generated
//! tokens per simulated second — deterministic, so it doubles as a
//! coarse cost-model trajectory). The 6-encoder scenario additionally
//! runs at threads=1 vs threads=N with a report-equality assertion: the
//! decode feedback edge lives entirely on the evaluation FPGA, so the
//! sharded engine's bit-identity contract must survive generation.

use galapagos_llm::serve::{
    run_serving, ArrivalProcess, DecodeConfig, LengthDist, ServeConfig, ServingReport,
};
use galapagos_llm::util::bench::Bencher;
use galapagos_llm::util::json::Json;
use galapagos_llm::{cycles_to_us, util::cli::Args, FABRIC_CLOCK_HZ};

struct Scenario {
    name: &'static str,
    encoders: usize,
    max_new_tokens: u32,
    /// offered load as a fraction of the measured prefill capacity
    /// (token passes add load on top, so these sit below the prefill
    /// bench's operating points)
    load: f64,
    requests: usize,
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.bool_or("quick", false)?;
    let out_path = args.str_or("out", "BENCH_decode.json");
    let seed = args.u64_or("seed", 7)?;
    let mut b = Bencher::quick();

    let scenarios = [
        Scenario {
            name: "glue decode 2enc n4 60%",
            encoders: 2,
            max_new_tokens: 4,
            load: 0.6,
            requests: 64,
        },
        Scenario {
            name: "glue decode 6enc n8 60%",
            encoders: 6,
            max_new_tokens: 8,
            load: 0.6,
            requests: 48,
        },
        Scenario {
            name: "glue decode 6enc n0 (pure prefill) 60%",
            encoders: 6,
            max_new_tokens: 0,
            load: 0.6,
            requests: 48,
        },
    ];

    let mut cases: Vec<Json> = Vec::new();
    let mut headlines: Vec<(String, f64)> = Vec::new();
    for s in &scenarios {
        let requests = if quick { (s.requests / 8).max(8) } else { s.requests };
        let mut cfg = ServeConfig::glue(s.encoders, requests, 1.0, seed);
        cfg.traffic.lengths = LengthDist::Glue;
        cfg.decode = Some(DecodeConfig { max_new_tokens: s.max_new_tokens });
        let (_mean_m, capacity) = cfg.capacity_at_mean()?;
        let rate = capacity * s.load;
        cfg.traffic.process = ArrivalProcess::Poisson { seqs_per_s: rate };

        let t0 = std::time::Instant::now();
        let report = b.once(s.name, || run_serving(&cfg))?;
        let wall = t0.elapsed();
        let d = report.decode.as_ref().expect("decode runs report the v4 decode section");
        // simulated decode throughput: generated tokens per simulated
        // second (deterministic — a cost-model number, not wall clock)
        let decode_tokens_per_s =
            d.generated_tokens as f64 * FABRIC_CLOCK_HZ as f64 / report.makespan_cycles.max(1) as f64;
        println!(
            "    TTFT p50 {:>8.1} us  p99 {:>8.1} us   ITL p50 {:>7.1} us  p99 {:>7.1} us   \
             {:>8.0} tokens/s generated",
            cycles_to_us(d.ttft.p50),
            cycles_to_us(d.ttft.p99),
            cycles_to_us(d.itl.p50),
            cycles_to_us(d.itl.p99),
            decode_tokens_per_s,
        );
        let mut case = match report.to_json() {
            Json::Obj(kv) => kv,
            _ => unreachable!("report serializes to an object"),
        };
        case.insert(0, ("scenario".into(), Json::Str(s.name.into())));
        case.push(("capacity_seqs_per_s".into(), Json::Num(capacity)));
        case.push(("load".into(), Json::Num(s.load)));
        case.push(("wall_ms".into(), Json::Num(wall.as_secs_f64() * 1e3)));
        case.push(("decode_tokens_per_s".into(), Json::Num(decode_tokens_per_s)));
        cases.push(Json::Obj(case));

        // the deep scenario doubles as the thread-invariance headline:
        // threads=1 vs threads=N on identical decode traffic, asserting
        // byte-identical reports (the crown-jewel contract extends to
        // the feedback loop), plus the simulated-throughput trajectory
        if s.encoders == 6 && s.max_new_tokens > 0 {
            headlines.push(("decode_tokens_per_s_6enc_n8".into(), decode_tokens_per_s));
            let threads = galapagos_llm::util::pool::sim_threads().max(2);
            let run_best = |n: usize| -> anyhow::Result<(f64, ServingReport)> {
                let mut cfg = cfg.clone();
                cfg.threads = Some(n);
                let mut best = f64::INFINITY;
                let mut last = None;
                for _ in 0..3 {
                    let t0 = std::time::Instant::now();
                    last = Some(run_serving(&cfg)?);
                    best = best.min(t0.elapsed().as_secs_f64());
                }
                Ok((best, last.unwrap()))
            };
            let (seq_wall, seq) = run_best(1)?;
            let (par_wall, par) = run_best(threads)?;
            anyhow::ensure!(
                seq.to_json().pretty() == par.to_json().pretty(),
                "parallel decode report diverged from sequential at threads={threads}"
            );
            let speedup = seq_wall / par_wall.max(1e-9);
            println!(
                "    sharded engine: {:.0} -> {:.0} events/s at {threads} threads \
                 ({speedup:.2}x best-of-3, reports identical)",
                seq.events as f64 / seq_wall.max(1e-9),
                par.events as f64 / par_wall.max(1e-9),
            );
            headlines.push(("parallel_decode_6enc_speedup".into(), speedup));
        }
    }

    let doc = Json::obj(vec![
        ("schema", Json::Str("bench_decode/v1".into())),
        ("mode", Json::Str(if quick { "quick" } else { "full" }.into())),
        ("seed", Json::Num(seed as f64)),
        ("sim_threads", Json::Num(galapagos_llm::util::pool::sim_threads() as f64)),
        ("cases", Json::Arr(cases)),
        (
            "headlines",
            Json::Obj(headlines.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
        ),
    ]);

    // --check: read the committed baseline before overwriting it
    let regressions = galapagos_llm::util::bench::load_check(&args, &doc, &out_path)?;
    std::fs::write(&out_path, doc.pretty())?;
    println!("\nwrote {out_path}");
    galapagos_llm::util::bench::report_check(regressions)?;
    Ok(())
}
