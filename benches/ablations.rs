//! Design-choice ablations (DESIGN.md experiment index extensions):
//! the latency/resource trades the paper leaves implicit.
//!
//!   A1: linear MAC-array width  — latency vs DSP cost (the Layer
//!       Description File's headline knob, §6.1)
//!   A2: attention NUM_PE        — the §7.1.2 padding formula in action
//!   A3: scatter policy          — Block vs RoundRobin row distribution
//!   A4: switch chaining         — d per extra hop in the encoder chain

use galapagos_llm::cluster_builder::layer_builder::fpga_reports;
use galapagos_llm::cycles_to_us;
use galapagos_llm::eval::testbed::{build_testbed, run_encoder_once, TestbedConfig};
use galapagos_llm::fpga::resources::Device;
use galapagos_llm::gmi::Out;
use galapagos_llm::ibert::graph::{build_encoder, EncoderGraphParams};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::timing::PeConfig;
use galapagos_llm::sim::packet::GlobalKernelId;
use galapagos_llm::util::bench::Bencher;
use galapagos_llm::util::table::{f2, Table};

fn run_with(pe: PeConfig, m: usize) -> (u64, u64) {
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
    cfg.pe = pe;
    let r = run_encoder_once(&cfg).unwrap();
    (r.x, r.t)
}

fn main() {
    let mut b = Bencher::quick();

    // A1: MAC-array width of the 768x768 linears
    let t1 = b.once("A1: linear MAC sweep", || {
        let mut t = Table::new(
            "A1 — linear MAC-array width vs encoder latency and DSP (m=128)",
            &["linear MACs", "T (us)", "QKV-FPGA DSP util", "fits?"],
        );
        for macs in [192u64, 384, 768, 1536] {
            let pe = PeConfig { linear_macs: macs, ..Default::default() };
            let (_, tt) = run_with(pe, 128);
            let cluster = build_encoder(&EncoderGraphParams {
                cluster_id: 0,
                fpga_base: 0,
                pe,
                mode: Mode::Timing,
                out_dst: Out::to(GlobalKernelId::new(200, 2)),
                max_seq: 128,
                hidden: 768,
                ffn: 3072,
                decode: None,
            })
            .cluster;
            let r = &fpga_reports(&cluster, &pe, Device::Xczu19eg, 128, 768, 3072)[0];
            t.row(vec![
                macs.to_string(),
                f2(cycles_to_us(tt)),
                format!("{:.1}%", r.utilisation().3 * 100.0),
                if r.fits() { "yes".into() } else { "NO".into() },
            ]);
        }
        t
    });
    println!("\n{}", t1.render());

    // A2: attention NUM_PE and the minimum-padding formula
    let t2 = b.once("A2: attention NUM_PE sweep", || {
        let mut t = Table::new(
            "A2 — attention NUM_PE: per-row cycles at MRPC-average m=54 (padding to NUM_PE*ceil(54/NUM_PE))",
            &["NUM_PE", "padded rows", "attn row cycles", "encoder T (us, m=54)"],
        );
        for pes in [8u64, 16, 32, 64] {
            let pe = PeConfig { attn_pes: pes, ..Default::default() };
            let padded = pes * 54u64.div_ceil(pes);
            let (_, tt) = run_with(pe, 54);
            t.row(vec![
                pes.to_string(),
                padded.to_string(),
                pe.attn_row_cycles(54, 64).to_string(),
                f2(cycles_to_us(tt)),
            ]);
        }
        t
    });
    println!("\n{}", t2.render());

    // A4: switches in series — each extra hop adds d = 1.1 us per Eq. 1
    let t4 = b.once("A4: switch chaining", || {
        let mut t = Table::new(
            "A4 — FPGAs per switch: encoder-chain first-output latency (2 encoders, m=32)",
            &["FPGAs/switch", "switches", "X (us)"],
        );
        for per in [2usize, 6, 13] {
            let mut cfg = TestbedConfig::proof_of_concept(32, Mode::Timing);
            cfg.encoders = 2;
            cfg.fpgas_per_switch = per;
            let mut tb = build_testbed(&cfg).unwrap();
            tb.sim.start();
            tb.sim.run().unwrap();
            let (x, _, _) = tb.sim.trace.xti(tb.sink_id).unwrap();
            let switches =
                tb.spec.switch_of.values().collect::<std::collections::HashSet<_>>().len();
            t.row(vec![per.to_string(), switches.to_string(), f2(cycles_to_us(x))]);
        }
        t
    });
    println!("\n{}", t4.render());
    println!("(A3 scatter-policy equivalence is property-tested in rust/tests/proptests.rs)");
}
